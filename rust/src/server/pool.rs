//! Per-peer persistent connection pool for the RPC stack (DESIGN.md
//! §Wire).
//!
//! PR 2 made the payloads cheap; this layer makes the *calls* cheap. The
//! small-call-heavy paths (agent arm rounds, shard probes, status polls)
//! previously paid a fresh `TcpStream::connect` — and, for the
//! coordinator, an optimistic-send-or-fallback wire dance — on every RPC.
//! A [`ConnPool`] instead keeps up to `max_idle_per_peer` negotiated
//! connections parked per peer:
//!
//! * **Negotiation happens once per connection.** A binary-preferring
//!   pool sends one v1 `hello {wire, version}` on each fresh dial; the
//!   agreed [`WireMode`] rides with the connection for its lifetime, so
//!   no call ever sends v2 frames blind. A peer that refuses binary (or
//!   predates `hello`) leaves the connection on v1 and counts one
//!   `wire.json_fallbacks`.
//! * **Stale connections are detected, evicted, and re-dialed.** A
//!   checkout probes the parked socket with a non-blocking peek (a
//!   restarted peer shows EOF); a call that dies mid-flight on a *reused*
//!   connection with a dead-socket error is retried exactly once on a
//!   fresh dial. Errors on fresh connections propagate unchanged, so the
//!   cluster's mark-dead / re-dispatch semantics are preserved
//!   bit-for-bit.
//! * **Idle hygiene.** Connections parked longer than `idle_timeout_ms`
//!   are closed at the next checkout; `invalidate` drops a peer's whole
//!   idle set (worker re-registration, observed death).
//! * **Redial backoff.** Consecutive dial *failures* to a peer open a
//!   capped, exponentially growing wait window (25 ms doubling to
//!   400 ms, deterministically jittered per `(addr, streak)` so a fleet
//!   of clients never thunders in phase). A dial inside an open window
//!   sleeps out the remainder first — a dead peer cannot be hot-loop
//!   dialed during recovery — while the first dial after any success is
//!   always immediate, so the happy path pays nothing.
//!
//! * **Request-id multiplexing (PR 8).** Against a peer whose `hello`
//!   grants `"mux": true`, the pool keeps **one** [`MuxConn`] per peer
//!   and interleaves every concurrent RPC on it: a writer tags frames
//!   with the envelope `id` (end-to-end correlation since PR 6), and
//!   whichever waiter holds the reader demultiplexes replies into
//!   per-request completion slots — no background pump thread. Callers
//!   can fire-and-await with [`ConnPool::start`]/[`ConnPool::wait`]
//!   (the coordinator's scatter path) or keep using `call*` unchanged.
//!   Old peers, JSON-wire peers, and `max_idle_per_peer: 0` pools fall
//!   back to the classic one-RPC-per-connection path transparently.
//!
//! Metrics (when constructed with a registry): `pool.hits`, `pool.dials`,
//! `pool.evictions`, `pool.retries`, `pool.keepalive_probes`,
//! `pool.backoff_ms` counters and the `pool.in_flight` gauge. Keepalive
//! probes (`probe_peer`) never count as dials: the dials-per-scatter pin
//! stays meaningful with background health checking on. The mux plane
//! adds `mux.frames` (replies demultiplexed), the `mux.in_flight` gauge,
//! and the `mux.head_of_line_ms` timing (routed-reply to waiter-pickup
//! lag — how long completed replies sat behind the demux loop).

use std::collections::{HashMap, VecDeque};
use std::io::ErrorKind;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::json::{Map, Value};
use crate::metrics::Registry;
use crate::util::fnv1a;

use super::rpc::{self, RpcError};
use super::wire::{self, Body, Payload, WireMode};

/// `[server.pool]` knobs (DESIGN.md §Wire).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolConfig {
    /// Idle connections kept per peer. `0` disables reuse entirely —
    /// every call dials, negotiates (one `hello` round trip), and
    /// closes. Kept as an escape hatch and for parity testing; note it
    /// is *costlier* than the pre-pool coordinator, which sent
    /// optimistically without a negotiation round trip.
    pub max_idle_per_peer: usize,
    /// Idle connections parked longer than this are closed at the next
    /// checkout.
    pub idle_timeout_ms: u64,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { max_idle_per_peer: 4, idle_timeout_ms: 30_000 }
    }
}

/// Default per-candidate-address connect timeout.
pub const DIAL_TIMEOUT: Duration = Duration::from_secs(5);
/// Read deadline for the dial-time `hello`: a peer that accepts TCP but
/// never answers must fail the dial, not hang it.
const HELLO_TIMEOUT: Duration = Duration::from_secs(10);

/// Resolve `addr` ("host:port") and connect, TCP_NODELAY set — the
/// single dialing path shared by pooled RPCs and the servers' shutdown
/// wakeups, so liveness behavior cannot diverge between "real" and
/// bookkeeping connections. Every resolved candidate address is tried
/// (an instant refusal on `::1` falls through to `127.0.0.1`), but
/// `timeout` bounds the *total* time across all of them, so a
/// black-holed multi-address peer still fails within one timeout —
/// dead-peer detection latency matches a single-address dial.
pub fn dial(addr: &str, timeout: Duration) -> Result<TcpStream, RpcError> {
    let deadline = Instant::now() + timeout;
    let mut last: Option<std::io::Error> = None;
    for sock in addr
        .to_socket_addrs()
        .map_err(|e| RpcError::Malformed(format!("bad peer address '{addr}': {e}")))?
    {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            // deadline burned before this attempt (e.g. slow DNS inside
            // to_socket_addrs, or earlier candidates): that's a timeout,
            // not a bad address
            last = last.or_else(|| {
                Some(std::io::Error::new(
                    ErrorKind::TimedOut,
                    format!("dial deadline exhausted before connecting to '{addr}'"),
                ))
            });
            break;
        }
        match TcpStream::connect_timeout(&sock, left) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) => last = Some(e),
        }
    }
    Err(match last {
        Some(e) => RpcError::Io(e),
        None => RpcError::Malformed(format!("address '{addr}' resolved to nothing")),
    })
}

/// A connection checked out of the pool. Return it with
/// [`ConnPool::checkin`] after a successful exchange; drop it on failure
/// (the socket state is unknown mid-protocol).
pub struct PooledConn {
    stream: TcpStream,
    /// Wire encoding negotiated once for this connection's lifetime.
    mode: WireMode,
    next_id: u64,
    /// Came from the idle set (vs freshly dialed) — drives the
    /// retry-once policy.
    reused: bool,
    generation: u64,
}

impl PooledConn {
    pub fn mode(&self) -> WireMode {
        self.mode
    }

    pub fn is_reused(&self) -> bool {
        self.reused
    }
}

struct IdleConn {
    stream: TcpStream,
    mode: WireMode,
    next_id: u64,
    parked_at: Instant,
}

#[derive(Default)]
struct PeerState {
    idle: Vec<IdleConn>,
    /// Live multiplexed connection (v2+mux peers): one socket shared by
    /// every concurrent RPC to this peer.
    mux: Option<Arc<MuxConn>>,
    /// The peer answered `hello` without granting mux (old peer, JSON
    /// wire, or `server.wire.mux: false`): stop re-asking on every call.
    /// Cleared by `invalidate` — a restarted peer may have upgraded.
    mux_refused: bool,
    /// Serializes mux dial attempts so a thundering herd of first calls
    /// to a peer yields one shared connection, not one socket per
    /// caller. Held only around the dial + install, never across RPCs.
    mux_dialing: Arc<Mutex<()>>,
    /// Bumped by `invalidate`; a checkout from an older generation is
    /// dropped at checkin instead of being pooled.
    generation: u64,
    /// Consecutive dial failures since the last successful dial — the
    /// redial-backoff exponent. Only TCP connect failures count;
    /// negotiation errors have their own bounded `hello` deadline.
    fail_streak: u32,
    /// When the streak's latest failure happened; the backoff window is
    /// measured from here, so time already spent elsewhere (e.g. the
    /// failed dial's own timeout) is credited against the wait.
    last_fail: Option<Instant>,
}

/// Backoff floor: the window after the first failed dial.
const BACKOFF_BASE_MS: u64 = 25;
/// Backoff ceiling: windows stop growing here so a long-dead peer's
/// eventual recovery is noticed within half a second.
const BACKOFF_CAP_MS: u64 = 400;

/// The jittered wait window before dial attempt `streak + 1`:
/// `min(25ms * 2^(streak-1), 400ms)`, scaled into `[1/2, 1]` of itself by
/// a hash of `(addr, streak)`. Deterministic on purpose — no RNG state,
/// reproducible in tests — while still decorrelating different clients
/// (different hash inputs) so they cannot redial a recovering peer in
/// lockstep.
fn backoff_wait_ms(addr: &str, streak: u32) -> u64 {
    debug_assert!(streak >= 1);
    let raw = BACKOFF_BASE_MS
        .saturating_mul(1u64 << (streak.saturating_sub(1)).min(10))
        .min(BACKOFF_CAP_MS);
    let h = fnv1a(addr.as_bytes()) ^ (streak as u64);
    // factor in [1/2, 1): wait = raw/2 + raw/2 * (h % 1024)/1024
    raw / 2 + (raw / 2).saturating_mul(h % 1024) / 1024
}

/// Read timeout on the shared mux socket: the demux pump wakes at least
/// this often to re-check deadlines and connection death, so a silent
/// peer cannot pin the pumping waiter forever.
const MUX_PUMP_READ_TIMEOUT: Duration = Duration::from_millis(25);
/// How long a non-pumping waiter parks on the condvar before retrying
/// for the reader lock (the previous pump holder may have exited after
/// its own reply arrived, leaving nobody pumping).
const MUX_FOLLOWER_WAIT: Duration = Duration::from_millis(5);
/// Abandoned (deadline-elapsed) request ids remembered so their late
/// replies are dropped instead of killing the connection as unknown.
/// Bounded: a flood of timeouts forgets the oldest ids, and a
/// forgotten-then-answered id tears the connection down — safe, just
/// slower than the common case.
const MUX_ABANDONED_CAP: usize = 1024;

struct MuxSlot {
    done: Option<Result<Body, RpcError>>,
    /// When the reply landed in the slot — the pickup lag feeds
    /// `mux.head_of_line_ms`.
    routed_at: Option<Instant>,
}

/// One live server-push subscription riding the mux demux (DESIGN.md
/// §Events): unsolicited `{"id", "seq", "event"}` frames from the peer
/// land in `queue`; a `{"id", "end"}` frame (or an error reply addressed
/// to the subscription id) finishes it.
struct SubState {
    /// Delivered-but-unconsumed events, oldest first, as `(seq, event)`.
    queue: VecDeque<(u64, Value)>,
    /// Terminal outcome once the peer finished the stream: `Ok(reason)`
    /// for a clean end, `Err(error)` for a remote error. Queued events
    /// are still drained before the terminal is surfaced.
    fin: Option<Result<String, String>>,
}

struct MuxState {
    /// In-flight request id → completion slot. Registered *before* the
    /// request bytes go out, so a reply can never race its own slot.
    slots: HashMap<u64, MuxSlot>,
    /// Live subscription id → event inbox. Registered in the same
    /// state-lock critical section as the subscribe request's slot, so a
    /// pushed event can never race its own inbox.
    subs: HashMap<u64, SubState>,
    /// Deadline-abandoned ids whose replies may still arrive.
    abandoned: VecDeque<u64>,
    /// Set once, never cleared: why this connection can take no more
    /// requests. Every parked waiter is woken to read it.
    dead: Option<String>,
}

struct MuxReader {
    stream: TcpStream,
    /// Partial-frame bytes carried across pump passes (a frame may span
    /// many reads; whichever waiter pumps next continues the buffer).
    buf: Vec<u8>,
}

/// One multiplexed connection: a single negotiated v2 socket carrying
/// many concurrent RPCs, replies demultiplexed by envelope id.
///
/// There is deliberately **no background reader thread** — a dedicated
/// pump per peer would re-create the thread-per-connection cost this
/// layer exists to remove. Instead the waiters themselves drive the
/// socket: whoever grabs the reader lock pumps frames for everyone
/// (routing each reply to its slot and waking the condvar); the rest
/// park on the condvar with a short timeout so the pump role is handed
/// off when its holder's own reply arrives. With zero waiters nothing
/// reads, which is fine: nothing is owed any bytes.
pub struct MuxConn {
    addr: String,
    next_id: AtomicU64,
    /// Writer half (cloned fd): one frame writes out at a time, so
    /// concurrent requests interleave at frame — not byte — granularity.
    writer: Mutex<TcpStream>,
    reader: Mutex<MuxReader>,
    /// Third fd clone used for liveness peeks and for `shutdown(Both)`
    /// on kill, which unblocks a reader waiting inside a pump pass.
    probe: TcpStream,
    state: Mutex<MuxState>,
    cv: Condvar,
    metrics: Option<Arc<Registry>>,
    tracer: Option<Arc<crate::trace::Tracer>>,
}

impl MuxConn {
    /// Wrap a freshly negotiated (binary, mux-granted) connection.
    fn new(
        addr: &str,
        conn: PooledConn,
        metrics: Option<Arc<Registry>>,
        tracer: Option<Arc<crate::trace::Tracer>>,
    ) -> Result<Arc<MuxConn>, RpcError> {
        let writer = conn.stream.try_clone()?;
        let probe = conn.stream.try_clone()?;
        conn.stream.set_read_timeout(Some(MUX_PUMP_READ_TIMEOUT)).ok();
        Ok(Arc::new(MuxConn {
            addr: addr.to_string(),
            next_id: AtomicU64::new(conn.next_id),
            writer: Mutex::new(writer),
            reader: Mutex::new(MuxReader { stream: conn.stream, buf: Vec::new() }),
            probe,
            state: Mutex::new(MuxState {
                slots: HashMap::new(),
                subs: HashMap::new(),
                abandoned: VecDeque::new(),
                dead: None,
            }),
            cv: Condvar::new(),
            metrics,
            tracer,
        }))
    }

    fn state(&self) -> MutexGuard<'_, MuxState> {
        // a waiter panicking while holding the state lock must not turn
        // every other in-flight call into a poison panic
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn gauge(&self, name: &str, delta: i64) {
        if let Some(m) = &self.metrics {
            let c = m.counter(name);
            if delta >= 0 {
                c.fetch_add(delta as u64, Ordering::Relaxed);
            } else {
                c.fetch_sub((-delta) as u64, Ordering::Relaxed);
            }
        }
    }

    fn is_dead(&self) -> bool {
        self.state().dead.is_some()
    }

    /// Parked (no in-flight requests or live subscriptions) with a
    /// socket that shows EOF or unsolicited bytes — the peer restarted
    /// under an idle connection. Never peeks while requests or
    /// subscriptions are live: a pending reply's (or pushed event's)
    /// bytes would read as "unsolicited".
    fn idle_and_stale(&self) -> bool {
        {
            let st = self.state();
            if st.dead.is_some() || !st.slots.is_empty() || !st.subs.is_empty() {
                return false;
            }
        }
        stream_is_stale(&self.probe)
    }

    /// Liveness answer for `probe_peer`: in-flight traffic (requests or
    /// subscriptions) counts as alive without touching the socket.
    fn is_live(&self) -> bool {
        {
            let st = self.state();
            if st.dead.is_some() {
                return false;
            }
            if !st.slots.is_empty() || !st.subs.is_empty() {
                return true;
            }
        }
        !stream_is_stale(&self.probe)
    }

    /// Declare the connection unusable (first reason wins), unblock any
    /// reader mid-pump via socket shutdown, and wake every waiter so
    /// they all observe death promptly.
    fn kill(&self, why: &str) {
        {
            let mut st = self.state();
            if st.dead.is_none() {
                st.dead = Some(why.to_string());
            }
        }
        let _ = self.probe.shutdown(Shutdown::Both);
        self.cv.notify_all();
    }

    fn dead_err(&self, why: &str) -> RpcError {
        // ConnectionAborted: lands in `is_dead_socket`, so callers'
        // retry-once-on-reused semantics match the classic pooled path
        RpcError::Io(std::io::Error::new(
            ErrorKind::ConnectionAborted,
            format!("mux connection to {}: {why}", self.addr),
        ))
    }

    /// Send one request and register its completion slot. The slot goes
    /// in before any byte is written, so the demux loop always finds a
    /// home for the reply no matter how fast it comes back.
    fn begin(&self, method: &str, params: &Payload) -> Result<u64, RpcError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        {
            let mut st = self.state();
            if let Some(why) = st.dead.clone() {
                return Err(self.dead_err(&why));
            }
            st.slots.insert(id, MuxSlot { done: None, routed_at: None });
        }
        self.gauge("mux.in_flight", 1);
        let res = {
            let mut w = self.writer.lock().unwrap_or_else(|p| p.into_inner());
            rpc::send_request_wire(
                &mut *w,
                id,
                method,
                params,
                WireMode::Binary,
                self.metrics.as_deref(),
            )
        };
        if let Err(e) = res {
            self.state().slots.remove(&id);
            self.gauge("mux.in_flight", -1);
            self.kill(&format!("request write failed: {e}"));
            return Err(e);
        }
        Ok(id)
    }

    /// [`MuxConn::begin`] that also registers a subscription inbox under
    /// the request's id, in the same state-lock critical section as the
    /// reply slot — so pushed events arriving before (or racing) the
    /// subscribe reply are queued, never dropped or treated as desync.
    fn begin_sub(&self, method: &str, params: &Payload) -> Result<u64, RpcError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        {
            let mut st = self.state();
            if let Some(why) = st.dead.clone() {
                return Err(self.dead_err(&why));
            }
            st.slots.insert(id, MuxSlot { done: None, routed_at: None });
            st.subs.insert(id, SubState { queue: VecDeque::new(), fin: None });
        }
        self.gauge("mux.in_flight", 1);
        let res = {
            let mut w = self.writer.lock().unwrap_or_else(|p| p.into_inner());
            rpc::send_request_wire(
                &mut *w,
                id,
                method,
                params,
                WireMode::Binary,
                self.metrics.as_deref(),
            )
        };
        if let Err(e) = res {
            {
                let mut st = self.state();
                st.slots.remove(&id);
                st.subs.remove(&id);
            }
            self.gauge("mux.in_flight", -1);
            self.kill(&format!("request write failed: {e}"));
            return Err(e);
        }
        Ok(id)
    }

    /// Drop subscription `id`'s inbox: subsequent pushes for it are
    /// silently discarded by `route_frame`.
    fn unsubscribe(&self, id: u64) {
        self.state().subs.remove(&id);
    }

    /// Block until subscription `id` yields its next event, ends, the
    /// connection dies, or `deadline` passes (`Idle` — the subscription
    /// stays live). Participates in the waiter-driven pump exactly like
    /// [`MuxConn::wait`], so a lone subscriber keeps the socket drained.
    fn sub_next(&self, id: u64, deadline: Option<Instant>) -> Result<SubEvent, RpcError> {
        loop {
            {
                let mut st = self.state();
                match st.subs.get_mut(&id) {
                    Some(sub) => {
                        if let Some((seq, value)) = sub.queue.pop_front() {
                            return Ok(SubEvent::Event { seq, value });
                        }
                        if let Some(fin) = sub.fin.take() {
                            st.subs.remove(&id);
                            return match fin {
                                Ok(reason) => Ok(SubEvent::End(reason)),
                                Err(e) => Err(RpcError::from_remote(&e)),
                            };
                        }
                    }
                    None => return Err(self.dead_err("subscription slot lost")),
                }
                if let Some(why) = st.dead.clone() {
                    st.subs.remove(&id);
                    drop(st);
                    return Err(self.dead_err(&why));
                }
                if deadline.is_some_and(|d| Instant::now() >= d) {
                    return Ok(SubEvent::Idle);
                }
            }
            match self.reader.try_lock() {
                Ok(mut r) => self.pump_once(&mut r),
                Err(std::sync::TryLockError::Poisoned(p)) => self.pump_once(&mut p.into_inner()),
                Err(std::sync::TryLockError::WouldBlock) => {
                    let st = self.state();
                    let _ = self.cv.wait_timeout(st, MUX_FOLLOWER_WAIT);
                }
            }
        }
    }

    /// Forget an in-flight request (deadline elapsed, or its
    /// `PendingCall` was dropped unawaited): its slot is released now
    /// and its eventual reply will be dropped on arrival instead of
    /// counting as unknown.
    fn abandon(&self, id: u64) {
        let mut st = self.state();
        if st.slots.remove(&id).is_some() {
            st.abandoned.push_back(id);
            if st.abandoned.len() > MUX_ABANDONED_CAP {
                st.abandoned.pop_front();
            }
            drop(st);
            self.gauge("mux.in_flight", -1);
        }
    }

    /// Block until request `id` completes, the connection dies, or
    /// `deadline` passes. Implements the waiter-driven pump: try to
    /// become the reader; otherwise park briefly on the condvar.
    fn wait(&self, id: u64, deadline: Option<Instant>) -> Result<Body, RpcError> {
        loop {
            {
                let mut st = self.state();
                match st.slots.get_mut(&id) {
                    Some(slot) => {
                        if let Some(res) = slot.done.take() {
                            if let (Some(m), Some(at)) = (&self.metrics, slot.routed_at) {
                                m.time("mux.head_of_line_ms", at.elapsed());
                            }
                            st.slots.remove(&id);
                            drop(st);
                            self.gauge("mux.in_flight", -1);
                            return res;
                        }
                    }
                    // slot vanished without completing (shouldn't
                    // happen; defensively treat as a dead conn)
                    None => {
                        drop(st);
                        self.gauge("mux.in_flight", -1);
                        return Err(self.dead_err("request slot lost"));
                    }
                }
                if let Some(why) = st.dead.clone() {
                    st.slots.remove(&id);
                    drop(st);
                    self.gauge("mux.in_flight", -1);
                    return Err(self.dead_err(&why));
                }
                if deadline.is_some_and(|d| Instant::now() >= d) {
                    st.slots.remove(&id);
                    st.abandoned.push_back(id);
                    if st.abandoned.len() > MUX_ABANDONED_CAP {
                        st.abandoned.pop_front();
                    }
                    drop(st);
                    self.gauge("mux.in_flight", -1);
                    return Err(RpcError::Io(std::io::Error::new(
                        ErrorKind::TimedOut,
                        format!("mux request {id} to {} deadline elapsed", self.addr),
                    )));
                }
            }
            match self.reader.try_lock() {
                Ok(mut r) => self.pump_once(&mut r),
                Err(std::sync::TryLockError::Poisoned(p)) => self.pump_once(&mut p.into_inner()),
                Err(std::sync::TryLockError::WouldBlock) => {
                    // someone else is pumping; park until they route a
                    // frame (notify_all) or the handoff window elapses
                    let st = self.state();
                    let _ = self.cv.wait_timeout(st, MUX_FOLLOWER_WAIT);
                }
            }
        }
    }

    /// One bounded pass of the shared reader: read what's available
    /// (≤ the 25ms socket timeout), then drain and route every complete
    /// frame in the buffer.
    fn pump_once(&self, r: &mut MuxReader) {
        let mut chunk = [0u8; 64 * 1024];
        match std::io::Read::read(&mut r.stream, &mut chunk) {
            Ok(0) => {
                self.kill("connection closed by peer");
                return;
            }
            Ok(n) => r.buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                return
            }
            Err(e) => {
                self.kill(&format!("read failed: {e}"));
                return;
            }
        }
        loop {
            if r.buf.len() < 4 {
                return;
            }
            let len = u32::from_le_bytes([r.buf[0], r.buf[1], r.buf[2], r.buf[3]]) as usize;
            if len > rpc::MAX_FRAME {
                self.kill(&format!("oversized reply frame ({len} bytes)"));
                return;
            }
            if r.buf.len() < 4 + len {
                return;
            }
            let frame = r.buf[4..4 + len].to_vec();
            r.buf.drain(..4 + len);
            self.route_frame(frame);
        }
    }

    /// Decode one reply frame and deliver it: push frame (subscription
    /// inbox), completion slot (wake all), abandoned id (drop silently),
    /// anything else (protocol desync — kill). Remote errors and
    /// malformed results are per-request outcomes; an undecodable or
    /// id-less frame means the stream itself can no longer be trusted.
    fn route_frame(&self, frame: Vec<u8>) {
        let n = frame.len();
        let t0 = Instant::now();
        let (v, tensors, mode) = match wire::decode_frame(frame) {
            Ok(x) => x,
            Err(e) => {
                self.kill(&format!("undecodable reply: {e}"));
                return;
            }
        };
        rpc::note_rx(self.metrics.as_deref(), n, t0.elapsed(), mode);
        if let Some(m) = &self.metrics {
            m.counter("mux.frames").fetch_add(1, Ordering::Relaxed);
        }
        let Some(id) = v.get("id").and_then(Value::as_i64).map(|i| i as u64) else {
            self.kill("reply missing id");
            return;
        };
        // server-push frames (DESIGN.md §Events) carry "event"/"end"
        // instead of "result"/"error" and are addressed to a
        // subscription id, not an awaiting request slot. A push for a
        // subscription this side no longer holds (unsubscribed, or a
        // final event racing the drop) is discarded without killing the
        // connection — unlike a truly unknown *reply* id, push frames
        // are unsolicited by design.
        if v.get("event").is_some() || v.get("end").is_some() {
            let mut st = self.state();
            if let Some(sub) = st.subs.get_mut(&id) {
                if let Some(ev) = v.get("event") {
                    let seq =
                        v.get("seq").and_then(Value::as_i64).map(|s| s as u64).unwrap_or(0);
                    sub.queue.push_back((seq, ev.clone()));
                } else if let Some(reason) = v.get("end").and_then(Value::as_str) {
                    sub.fin = Some(Ok(reason.to_string()));
                }
                drop(st);
                self.cv.notify_all();
            }
            return;
        }
        let res: Result<Body, RpcError> =
            if let Some(e) = v.get("error").and_then(Value::as_str) {
                Err(RpcError::from_remote(e))
            } else {
                // move, don't clone: result can be a multi-MB matrix
                let (result, spans) = match v {
                    Value::Object(mut m) => (m.remove("result"), m.remove("trace_spans")),
                    _ => (None, None),
                };
                // adoption happens on whichever waiter pumps; parenting
                // lives in the span records themselves, so the adopting
                // thread's identity doesn't matter
                if let (Some(t), Some(sv)) = (self.tracer.as_deref(), spans) {
                    t.adopt(crate::trace::spans_from_value(&sv));
                }
                match result {
                    Some(value) => Ok(Body { value, tensors }),
                    None => Err(RpcError::Malformed("missing result".into())),
                }
            };
        let mut st = self.state();
        if let Some(slot) = st.slots.get_mut(&id) {
            slot.done = Some(res);
            slot.routed_at = Some(Instant::now());
            drop(st);
            self.cv.notify_all();
        } else if let Some(sub) = st.subs.get_mut(&id) {
            // an error reply addressed to a live subscription (slow
            // subscriber disconnect, job evicted): terminal for the
            // stream, not for the connection
            sub.fin = Some(match res {
                Err(e) => Err(e.to_string()),
                Ok(_) => Err("unexpected result frame on subscription".into()),
            });
            drop(st);
            self.cv.notify_all();
        } else if let Some(pos) = st.abandoned.iter().position(|&a| a == id) {
            st.abandoned.remove(pos);
            // late reply to a timed-out request: drop, conn stays usable
        } else {
            drop(st);
            self.kill(&format!("reply with unknown id {id}"));
        }
    }
}

/// Outcome of asking for the shared mux connection to a peer.
enum MuxObtained {
    /// Use the multiplexed plane; the flag is true when this very call
    /// dialed the connection (fresh — errors propagate, no retry).
    Mux(Arc<MuxConn>, bool),
    /// Use the classic path; a refusing dial's negotiated conn is
    /// donated back so it serves the caller's request directly.
    Classic(Option<PooledConn>),
}

/// One in-flight multiplexed RPC begun with [`ConnPool::start`]. Await
/// it with [`ConnPool::wait`]; dropping it unawaited abandons the
/// request (the reply, if it ever comes, is discarded).
pub struct PendingCall {
    mux: Arc<MuxConn>,
    id: u64,
    deadline: Option<Instant>,
    awaited: bool,
}

impl Drop for PendingCall {
    fn drop(&mut self) {
        if !self.awaited {
            self.mux.abandon(self.id);
        }
    }
}

/// One delivery from [`Subscription::next`].
#[derive(Debug)]
pub enum SubEvent {
    /// A pushed event: `seq` is the publisher's per-job sequence number,
    /// `value` the event record verbatim (DESIGN.md §Events).
    Event { seq: u64, value: Value },
    /// The peer finished the stream cleanly, with a reason.
    End(String),
    /// The per-call timeout elapsed with nothing pushed; the
    /// subscription is still live — call `next` again.
    Idle,
}

/// A live server-push subscription obtained with [`ConnPool::subscribe`].
/// Dropping it unsubscribes locally: later pushes for its id are
/// discarded by the demux instead of accumulating unread.
pub struct Subscription {
    mux: Arc<MuxConn>,
    id: u64,
}

impl Subscription {
    /// Block up to `timeout` for the next delivery. Connection death
    /// surfaces as the same `Io(ConnectionAborted)` a mux call would
    /// see, so callers' reconnect logic composes with the pool's.
    pub fn next(&self, timeout: Duration) -> Result<SubEvent, RpcError> {
        self.mux.sub_next(self.id, Some(Instant::now() + timeout))
    }
}

impl Drop for Subscription {
    fn drop(&mut self) {
        self.mux.unsubscribe(self.id);
    }
}

/// Thread-safe per-peer pool of persistent, wire-negotiated connections.
pub struct ConnPool {
    cfg: PoolConfig,
    /// Wire encoding this process asks peers for (`server.wire`).
    prefer: WireMode,
    dial_timeout: Duration,
    hello_timeout: Duration,
    metrics: Option<Arc<Registry>>,
    /// When set, span subtrees piggybacked on replies are adopted into
    /// this tracer (the coordinator's end-to-end tree assembly).
    tracer: Option<Arc<crate::trace::Tracer>>,
    /// Ask peers for request-id multiplexing at `hello` (`server.wire.mux`).
    /// Effective only on a binary-preferring pool with reuse enabled:
    /// `max_idle_per_peer: 0` means per-call dialing, which a shared
    /// long-lived mux socket would contradict.
    mux_enabled: bool,
    peers: Mutex<HashMap<String, PeerState>>,
}

impl ConnPool {
    pub fn new(cfg: PoolConfig, prefer: WireMode, metrics: Option<Arc<Registry>>) -> ConnPool {
        ConnPool {
            cfg,
            prefer,
            dial_timeout: DIAL_TIMEOUT,
            hello_timeout: HELLO_TIMEOUT,
            metrics,
            tracer: None,
            mux_enabled: true,
            peers: Mutex::new(HashMap::new()),
        }
    }

    /// Override both the connect and the `hello` deadlines (the client's
    /// `connect_timeout` surface).
    pub fn with_timeouts(mut self, dial: Duration, hello: Duration) -> ConnPool {
        self.dial_timeout = dial;
        self.hello_timeout = hello;
        self
    }

    /// Adopt remote span subtrees from replies into `tracer`.
    pub fn with_tracer(mut self, tracer: Arc<crate::trace::Tracer>) -> ConnPool {
        self.tracer = Some(tracer);
        self
    }

    /// Enable/disable asking peers for request-id multiplexing
    /// (`server.wire.mux`; default on).
    pub fn with_mux(mut self, on: bool) -> ConnPool {
        self.mux_enabled = on;
        self
    }

    /// Muxing applies on this pool at all (irrespective of any single
    /// peer's answer).
    fn mux_gate(&self) -> bool {
        self.mux_enabled && self.prefer == WireMode::Binary && self.cfg.max_idle_per_peer > 0
    }

    fn count(&self, name: &str, n: u64) {
        if let Some(m) = &self.metrics {
            m.counter(name).fetch_add(n, Ordering::Relaxed);
        }
    }

    fn registry(&self) -> Option<&Registry> {
        self.metrics.as_deref()
    }

    /// Idle connections currently parked for `addr` (tests/benches).
    pub fn idle_conns(&self, addr: &str) -> usize {
        self.peers.lock().unwrap().get(addr).map(|p| p.idle.len()).unwrap_or(0)
    }

    /// Drop every idle connection to `addr` and mark in-flight ones as
    /// non-poolable — for peers known to have restarted or died (worker
    /// re-registration, observed transport failure).
    pub fn invalidate(&self, addr: &str) {
        let mut peers = self.peers.lock().unwrap();
        if let Some(p) = peers.get_mut(addr) {
            p.generation += 1;
            if !p.idle.is_empty() {
                self.count("pool.evictions", p.idle.len() as u64);
                p.idle.clear();
            }
            if let Some(m) = p.mux.take() {
                self.count("pool.evictions", 1);
                m.kill("peer invalidated");
            }
            // the reborn peer may have a different mux answer
            p.mux_refused = false;
        }
    }

    /// Background keepalive/health probe: is `addr` alive right now? A
    /// healthy parked idle connection answers for free (non-blocking
    /// peek); otherwise one bounded dial is made and immediately closed.
    /// Probe dials count under `pool.keepalive_probes` — **never**
    /// `pool.dials`, so health checking cannot distort the
    /// dials-per-scatter invariant the cluster tests pin — and they
    /// neither negotiate nor park, so a probe can never change any
    /// connection's wire mode or the pool's contents. The coordinator's
    /// membership sweep uses this to evict a dead worker before a query
    /// pays the scatter dial timeout (DESIGN.md §Cluster).
    pub fn probe_peer(&self, addr: &str, timeout: Duration) -> bool {
        self.count("pool.keepalive_probes", 1);
        {
            let peers = self.peers.lock().unwrap();
            if let Some(p) = peers.get(addr) {
                if let Some(m) = &p.mux {
                    if m.is_live() {
                        return true;
                    }
                }
                if p.idle.iter().any(|c| !stream_is_stale(&c.stream)) {
                    return true;
                }
            }
        }
        dial(addr, timeout).is_ok()
    }

    /// Check out a connection to `addr`: the freshest live idle one, or a
    /// fresh dial (+ one-time wire negotiation) when none survives the
    /// idle-timeout and staleness checks.
    pub fn checkout(&self, addr: &str) -> Result<PooledConn, RpcError> {
        let idle_timeout = Duration::from_millis(self.cfg.idle_timeout_ms);
        loop {
            let (cand, generation) = {
                let mut peers = self.peers.lock().unwrap();
                let p = peers.entry(addr.to_string()).or_default();
                // age out from the oldest end first
                let before = p.idle.len();
                p.idle.retain(|c| c.parked_at.elapsed() <= idle_timeout);
                let aged = before - p.idle.len();
                if aged > 0 {
                    self.count("pool.evictions", aged as u64);
                }
                (p.idle.pop(), p.generation)
            };
            match cand {
                Some(c) => {
                    if stream_is_stale(&c.stream) {
                        // a restarted/dead peer: close and try the next
                        self.count("pool.evictions", 1);
                        continue;
                    }
                    self.count("pool.hits", 1);
                    return Ok(PooledConn {
                        stream: c.stream,
                        mode: c.mode,
                        next_id: c.next_id,
                        reused: true,
                        generation,
                    });
                }
                None => return self.dial_negotiated(addr, generation),
            }
        }
    }

    /// Park a connection for reuse. Dropped instead when pooling is off,
    /// the peer's idle set is full, or the peer was invalidated after
    /// this connection was checked out.
    pub fn checkin(&self, addr: &str, conn: PooledConn) {
        if self.cfg.max_idle_per_peer == 0 {
            return; // per-call mode: close by drop, nothing to count
        }
        // a per-call read deadline must not outlive the call that set
        // it: the next checkout would silently inherit a stale (possibly
        // much shorter) timeout and fail a perfectly healthy exchange
        conn.stream.set_read_timeout(None).ok();
        let mut peers = self.peers.lock().unwrap();
        let p = peers.entry(addr.to_string()).or_default();
        if conn.generation != p.generation || p.idle.len() >= self.cfg.max_idle_per_peer {
            self.count("pool.evictions", 1);
            return;
        }
        p.idle.push(IdleConn {
            stream: conn.stream,
            mode: conn.mode,
            next_id: conn.next_id,
            parked_at: Instant::now(),
        });
    }

    /// Dial + negotiate one fresh connection. The `hello` rides the new
    /// socket as v1 JSON (any peer can answer); a refusal or a pre-v2
    /// `unknown method` error leaves the connection on the JSON wire.
    fn dial_negotiated(&self, addr: &str, generation: u64) -> Result<PooledConn, RpcError> {
        self.dial_negotiated_ext(addr, generation, false).map(|(c, _)| c)
    }

    /// [`ConnPool::dial_negotiated`] that can also request request-id
    /// multiplexing in the same `hello`: the returned flag is true iff
    /// the peer echoed `"mux": true` (old peers skip the unknown key, so
    /// refusal is simply its absence — no extra round trip, no version
    /// matrix).
    fn dial_negotiated_ext(
        &self,
        addr: &str,
        generation: u64,
        want_mux: bool,
    ) -> Result<(PooledConn, bool), RpcError> {
        self.backoff_before_dial(addr);
        let mut stream = match dial(addr, self.dial_timeout) {
            Ok(s) => {
                self.note_dial_outcome(addr, true);
                s
            }
            Err(e) => {
                self.note_dial_outcome(addr, false);
                return Err(e);
            }
        };
        let mut next_id = 1u64;
        let mut mode = WireMode::Json;
        let mut mux = false;
        if self.prefer == WireMode::Binary {
            stream.set_read_timeout(Some(self.hello_timeout)).ok();
            let mut p = Map::new();
            p.insert("wire", Value::from(WireMode::Binary.as_str()));
            p.insert("version", Value::from(wire::WIRE_VERSION as u64));
            if want_mux {
                p.insert("mux", Value::Bool(true));
            }
            let id = next_id;
            next_id += 1;
            rpc::send_request_wire(
                &mut stream,
                id,
                "hello",
                &Payload::json(Value::Object(p)),
                WireMode::Json,
                self.registry(),
            )?;
            match rpc::recv_response_body(&mut stream, id, self.registry()) {
                Ok(b) => {
                    if b.value.get("wire").and_then(Value::as_str) == Some("binary") {
                        mode = WireMode::Binary;
                    }
                    mux = want_mux
                        && mode == WireMode::Binary
                        && b.value.get("mux").and_then(Value::as_bool) == Some(true);
                }
                // pre-v2 peer: no `hello` method — stay on JSON; any
                // other remote error is a real failure, not version skew
                Err(RpcError::Remote(msg)) if msg.contains("unknown method") => {}
                Err(e) => return Err(e),
            }
            stream.set_read_timeout(None).ok();
            if mode == WireMode::Json {
                // the peer cannot (or will not) speak v2: every call on
                // this connection now pays the slow JSON plane
                self.count("wire.json_fallbacks", 1);
            }
        }
        self.count("pool.dials", 1);
        Ok((PooledConn { stream, mode, next_id, reused: false, generation }, mux))
    }

    /// One blocking request/response exchange over a pooled connection,
    /// for an **idempotent** (safely re-sendable) method. Tensor payloads
    /// encode per the connection's negotiated mode (raw sections on v2,
    /// inlined JSON on v1). A dead-socket failure on a *reused*
    /// connection is retried once on a fresh dial; all other failures —
    /// including any failure of the fresh attempt — propagate, so
    /// callers' liveness handling sees exactly what a per-call dial
    /// would have seen.
    pub fn call(
        &self,
        addr: &str,
        method: &str,
        params: &Payload,
        read_timeout: Option<Duration>,
    ) -> Result<Body, RpcError> {
        self.call_negotiated(addr, method, params, read_timeout).map(|(b, _)| b)
    }

    /// [`ConnPool::call`], also reporting the connection's negotiated
    /// [`WireMode`] (clients mirror it for mode-sensitive encodes).
    pub fn call_negotiated(
        &self,
        addr: &str,
        method: &str,
        params: &Payload,
        read_timeout: Option<Duration>,
    ) -> Result<(Body, WireMode), RpcError> {
        self.call_gauged(addr, method, params, read_timeout, true)
    }

    /// [`ConnPool::call_negotiated`] for **non-idempotent** methods
    /// (`agent_start`): a parked connection dying mid-exchange is
    /// ambiguous — the server may already be running the request — so it
    /// surfaces as an error instead of being silently re-sent. The
    /// checkout-time staleness peek still rescues the common
    /// already-dead-socket case before anything is written.
    pub fn call_once(
        &self,
        addr: &str,
        method: &str,
        params: &Payload,
        read_timeout: Option<Duration>,
    ) -> Result<(Body, WireMode), RpcError> {
        self.call_gauged(addr, method, params, read_timeout, false)
    }

    fn call_gauged(
        &self,
        addr: &str,
        method: &str,
        params: &Payload,
        read_timeout: Option<Duration>,
        retry_stale: bool,
    ) -> Result<(Body, WireMode), RpcError> {
        let gauge = self.metrics.as_ref().map(|m| m.counter("pool.in_flight"));
        if let Some(g) = &gauge {
            g.fetch_add(1, Ordering::Relaxed);
        }
        let out = self.call_inner(addr, method, params, read_timeout, retry_stale);
        if let Some(g) = &gauge {
            g.fetch_sub(1, Ordering::Relaxed);
        }
        out
    }

    fn call_inner(
        &self,
        addr: &str,
        method: &str,
        params: &Payload,
        read_timeout: Option<Duration>,
        retry_stale: bool,
    ) -> Result<(Body, WireMode), RpcError> {
        let donated = match self.mux_obtain(addr)? {
            MuxObtained::Mux(mux, fresh) => {
                return match self.mux_roundtrip(&mux, method, params, read_timeout) {
                    Err(e) if retry_stale && !fresh && is_dead_socket(&e) => {
                        // the shared conn died under us: same retry-once
                        // policy as a reused classic conn. A downgraded
                        // peer (mux now refused) falls through to the
                        // classic path inside the recursive call, without
                        // a second retry budget.
                        self.invalidate(addr);
                        self.count("pool.retries", 1);
                        self.call_inner(addr, method, params, read_timeout, false)
                    }
                    other => other.map(|b| (b, WireMode::Binary)),
                };
            }
            MuxObtained::Classic(donated) => donated,
        };
        let mut conn = match donated {
            // the mux-refusing dial's conn, used directly: neither a
            // second dial nor a phantom pool.hit
            Some(c) => c,
            None => self.checkout(addr)?,
        };
        let reused = conn.reused;
        match self.roundtrip(&mut conn, method, params, read_timeout) {
            Ok(body) => {
                let mode = conn.mode;
                self.checkin(addr, conn);
                Ok((body, mode))
            }
            Err(e) if retry_stale && reused && is_dead_socket(&e) => {
                // the parked connection died under us (peer restart, idle
                // close): its siblings are just as old — flush them and
                // run the request once on a fresh dial. A genuinely dead
                // peer fails the dial and surfaces exactly as before.
                drop(conn);
                self.invalidate(addr);
                self.count("pool.retries", 1);
                let mut fresh = self.dial_and_track(addr)?;
                match self.roundtrip(&mut fresh, method, params, read_timeout) {
                    Ok(body) => {
                        let mode = fresh.mode;
                        self.checkin(addr, fresh);
                        Ok((body, mode))
                    }
                    Err(e2) => Err(e2),
                }
            }
            Err(e) => Err(e),
        }
    }

    /// Sleep out whatever remains of the peer's current backoff window.
    /// No-op when the streak is zero (first dial, or any dial after a
    /// success) or when the window already elapsed while the caller was
    /// doing other work. The lock is never held across the sleep.
    fn backoff_before_dial(&self, addr: &str) {
        let wait = {
            let peers = self.peers.lock().unwrap();
            let Some(p) = peers.get(addr) else { return };
            if p.fail_streak == 0 {
                return;
            }
            let Some(last) = p.last_fail else { return };
            Duration::from_millis(backoff_wait_ms(addr, p.fail_streak))
                .saturating_sub(last.elapsed())
        };
        if !wait.is_zero() {
            self.count("pool.backoff_ms", wait.as_millis() as u64);
            std::thread::sleep(wait);
        }
    }

    fn note_dial_outcome(&self, addr: &str, ok: bool) {
        let mut peers = self.peers.lock().unwrap();
        let p = peers.entry(addr.to_string()).or_default();
        if ok {
            p.fail_streak = 0;
            p.last_fail = None;
        } else {
            p.fail_streak = p.fail_streak.saturating_add(1);
            p.last_fail = Some(Instant::now());
        }
    }

    fn dial_and_track(&self, addr: &str) -> Result<PooledConn, RpcError> {
        let generation =
            self.peers.lock().unwrap().entry(addr.to_string()).or_default().generation;
        self.dial_negotiated(addr, generation)
    }

    fn roundtrip(
        &self,
        conn: &mut PooledConn,
        method: &str,
        params: &Payload,
        read_timeout: Option<Duration>,
    ) -> Result<Body, RpcError> {
        conn.stream.set_read_timeout(read_timeout).ok();
        let id = conn.next_id;
        conn.next_id += 1;
        rpc::send_request_wire(&mut conn.stream, id, method, params, conn.mode, self.registry())?;
        rpc::recv_response_traced(&mut conn.stream, id, self.registry(), self.tracer.as_deref())
    }

    /// The shared [`MuxConn`] for `addr`, dialing one when needed.
    /// `Classic` means the caller must use the one-RPC-per-connection
    /// path — muxing is gated off on this pool, or the peer refused it
    /// at `hello` (in which case the refusing dial's freshly negotiated
    /// conn rides along so it isn't wasted). The `Mux` flag is true when
    /// this call dialed the connection (fresh), driving the retry-once
    /// policy exactly like `PooledConn::is_reused` does for classic
    /// conns.
    fn mux_obtain(&self, addr: &str) -> Result<MuxObtained, RpcError> {
        if !self.mux_gate() {
            return Ok(MuxObtained::Classic(None));
        }
        let dialing = {
            let mut peers = self.peers.lock().unwrap();
            let p = peers.entry(addr.to_string()).or_default();
            if let Some(m) = &p.mux {
                if m.is_dead() || m.idle_and_stale() {
                    let dead = p.mux.take().unwrap();
                    dead.kill("stale while parked");
                    self.count("pool.evictions", 1);
                } else {
                    self.count("pool.hits", 1);
                    return Ok(MuxObtained::Mux(m.clone(), false));
                }
            }
            if p.mux_refused {
                return Ok(MuxObtained::Classic(None));
            }
            if !p.idle.is_empty() {
                // mux-ness unknown but classic conns are parked (direct
                // checkout users, pools warmed before the upgrade):
                // reuse them instead of dialing to ask — discovery waits
                // for a call that would have dialed anyway
                return Ok(MuxObtained::Classic(None));
            }
            p.mux_dialing.clone()
        };
        // serialize dials per peer: the herd's first caller dials, the
        // rest block here and then find the installed conn below
        let _dial = dialing.lock().unwrap_or_else(|p| p.into_inner());
        let generation = {
            let mut peers = self.peers.lock().unwrap();
            let p = peers.entry(addr.to_string()).or_default();
            if let Some(m) = &p.mux {
                if !m.is_dead() {
                    self.count("pool.hits", 1);
                    return Ok(MuxObtained::Mux(m.clone(), false));
                }
                p.mux = None;
            }
            if p.mux_refused || !p.idle.is_empty() {
                return Ok(MuxObtained::Classic(None));
            }
            p.generation
        };
        let (conn, granted) = self.dial_negotiated_ext(addr, generation, true)?;
        if !granted {
            // classic peer (old binary, JSON wire, or mux disabled
            // server-side): remember the refusal; the dialed conn goes
            // back to the caller for direct use
            self.peers.lock().unwrap().entry(addr.to_string()).or_default().mux_refused = true;
            return Ok(MuxObtained::Classic(Some(conn)));
        }
        let fresh = MuxConn::new(addr, conn, self.metrics.clone(), self.tracer.clone())?;
        self.peers.lock().unwrap().entry(addr.to_string()).or_default().mux = Some(fresh.clone());
        Ok(MuxObtained::Mux(fresh, true))
    }

    fn mux_roundtrip(
        &self,
        mux: &MuxConn,
        method: &str,
        params: &Payload,
        read_timeout: Option<Duration>,
    ) -> Result<Body, RpcError> {
        let deadline = read_timeout.map(|t| Instant::now() + t);
        let id = mux.begin(method, params)?;
        mux.wait(id, deadline)
    }

    /// Begin `method` on the shared mux connection to `addr` without
    /// blocking on the reply — the scatter path's fan-out primitive
    /// (fire every shard's request from one thread, then await them in
    /// turn with [`ConnPool::wait`]). `Ok(None)` means the peer doesn't
    /// multiplex and the caller must use the classic blocking path. A
    /// begin that fails on a previously live conn is retried once on a
    /// fresh dial: the request bytes never left, so re-sending is safe
    /// even for non-idempotent methods.
    pub fn start(
        &self,
        addr: &str,
        method: &str,
        params: &Payload,
        read_timeout: Option<Duration>,
    ) -> Result<Option<PendingCall>, RpcError> {
        let (mux, fresh) = match self.mux_obtain(addr)? {
            MuxObtained::Mux(m, fresh) => (m, fresh),
            MuxObtained::Classic(donated) => {
                if let Some(c) = donated {
                    self.checkin(addr, c);
                }
                return Ok(None);
            }
        };
        let deadline = read_timeout.map(|t| Instant::now() + t);
        match mux.begin(method, params) {
            Ok(id) => Ok(Some(PendingCall { mux, id, deadline, awaited: false })),
            Err(e) if !fresh && is_dead_socket(&e) => {
                self.invalidate(addr);
                self.count("pool.retries", 1);
                match self.mux_obtain(addr)? {
                    MuxObtained::Mux(m2, _) => {
                        let id = m2.begin(method, params)?;
                        Ok(Some(PendingCall { mux: m2, id, deadline, awaited: false }))
                    }
                    MuxObtained::Classic(donated) => {
                        // peer downgraded mid-retry: classic path
                        if let Some(c) = donated {
                            self.checkin(addr, c);
                        }
                        Ok(None)
                    }
                }
            }
            Err(e) => Err(e),
        }
    }

    /// Block for the reply of a call begun with [`ConnPool::start`].
    pub fn wait(&self, mut call: PendingCall) -> Result<Body, RpcError> {
        call.awaited = true;
        call.mux.wait(call.id, call.deadline)
    }

    /// Open a server-push subscription on the shared mux connection to
    /// `addr`: send `method` (e.g. `job_subscribe`), await its reply
    /// (the acknowledgment body), and return a [`Subscription`] whose
    /// `next` yields the frames the peer pushes under this request's id
    /// (DESIGN.md §Events). Push streams require the multiplexed wire —
    /// a classic peer gets a typed refusal, since unsolicited frames
    /// would corrupt a one-RPC-per-connection exchange.
    pub fn subscribe(
        &self,
        addr: &str,
        method: &str,
        params: &Payload,
        reply_timeout: Option<Duration>,
    ) -> Result<(Body, Subscription), RpcError> {
        let mux = match self.mux_obtain(addr)? {
            MuxObtained::Mux(m, _) => m,
            MuxObtained::Classic(donated) => {
                if let Some(c) = donated {
                    self.checkin(addr, c);
                }
                return Err(RpcError::Remote(format!(
                    "peer {addr} did not negotiate request multiplexing; \
                     push subscriptions unavailable"
                )));
            }
        };
        let id = mux.begin_sub(method, params)?;
        let deadline = reply_timeout.map(|t| Instant::now() + t);
        match mux.wait(id, deadline) {
            Ok(body) => Ok((body, Subscription { mux, id })),
            Err(e) => {
                mux.unsubscribe(id);
                Err(e)
            }
        }
    }

    /// Negotiate (or reuse) a connection to `addr` and report its wire
    /// mode without issuing an RPC — the client's connect-time
    /// handshake surface.
    pub fn establish(&self, addr: &str) -> Result<WireMode, RpcError> {
        let conn = match self.mux_obtain(addr)? {
            MuxObtained::Mux(..) => return Ok(WireMode::Binary),
            MuxObtained::Classic(Some(c)) => c,
            MuxObtained::Classic(None) => self.checkout(addr)?,
        };
        let mode = conn.mode();
        self.checkin(addr, conn);
        Ok(mode)
    }

    /// What is known about `addr`'s multiplexing without touching the
    /// network: `Some(true)` with a live mux conn, `Some(false)` when
    /// muxing is gated off on this pool or the peer refused it, `None`
    /// before first contact.
    pub fn peer_muxes(&self, addr: &str) -> Option<bool> {
        if !self.mux_gate() {
            return Some(false);
        }
        let peers = self.peers.lock().unwrap();
        let p = peers.get(addr)?;
        if let Some(m) = &p.mux {
            if !m.is_dead() {
                return Some(true);
            }
        }
        if p.mux_refused {
            return Some(false);
        }
        None
    }
}

/// Peer-closed detection without consuming stream bytes: a non-blocking
/// peek on a healthy idle connection yields `WouldBlock`; EOF, any other
/// error, or unsolicited bytes (protocol desync) all mean the connection
/// cannot carry another RPC.
fn stream_is_stale(s: &TcpStream) -> bool {
    if s.set_nonblocking(true).is_err() {
        return true;
    }
    let mut probe = [0u8; 1];
    let stale = match s.peek(&mut probe) {
        Ok(_) => true,
        Err(e) if e.kind() == ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = s.set_nonblocking(false);
    stale
}

/// Did this failure come from a socket that died between calls (as a
/// restarted peer's parked connection does)? Timeouts are deliberately
/// excluded: a slow peer must surface as slow, not be retried into
/// double execution.
fn is_dead_socket(e: &RpcError) -> bool {
    match e {
        RpcError::Closed => true,
        RpcError::Io(io) => matches!(
            io.kind(),
            ErrorKind::ConnectionReset
                | ErrorKind::ConnectionAborted
                | ErrorKind::BrokenPipe
                | ErrorKind::UnexpectedEof
                | ErrorKind::NotConnected
        ),
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::value::obj;
    use crate::util::mat::Mat;
    use std::net::{Shutdown, TcpListener};
    use std::sync::atomic::AtomicBool;

    /// Scripted RPC peer: answers `hello` per a flippable wire policy,
    /// echoes any other method, and records each non-hello request's
    /// encoding. Open sockets are tracked so a test can slam them shut
    /// (simulating a peer restart).
    struct MiniPeer {
        addr: String,
        seen: Arc<Mutex<Vec<WireMode>>>,
        wire: Arc<Mutex<WireMode>>,
        conns: Arc<Mutex<Vec<TcpStream>>>,
        shutdown: Arc<AtomicBool>,
    }

    impl MiniPeer {
        fn start(initial_wire: WireMode) -> MiniPeer {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            let seen = Arc::new(Mutex::new(Vec::new()));
            let wire = Arc::new(Mutex::new(initial_wire));
            let conns = Arc::new(Mutex::new(Vec::new()));
            let shutdown = Arc::new(AtomicBool::new(false));
            let (seen2, wire2, conns2, stop) =
                (seen.clone(), wire.clone(), conns.clone(), shutdown.clone());
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(mut stream) = stream else { continue };
                    conns2.lock().unwrap().push(stream.try_clone().unwrap());
                    let (seen, policy) = (seen2.clone(), wire2.clone());
                    std::thread::spawn(move || loop {
                        let Ok(buf) = rpc::read_frame(&mut stream) else { return };
                        let Ok(req) = rpc::decode_request_frame(buf) else { return };
                        let reply = if req.method == "hello" {
                            // never grants mux: MiniPeer's serial loop is
                            // exactly the classic one-RPC-at-a-time peer
                            Payload::json(wire::hello_reply(
                                &req.params.value,
                                *policy.lock().unwrap(),
                                false,
                            ))
                        } else {
                            seen.lock().unwrap().push(req.mode);
                            if req.method == "slow" {
                                let ms = req.params.value.get("ms").and_then(Value::as_i64);
                                std::thread::sleep(Duration::from_millis(ms.unwrap_or(0) as u64));
                            }
                            req.params.to_payload()
                        };
                        if rpc::send_result_wire(&mut stream, req.id, &reply, req.mode, None)
                            .is_err()
                        {
                            return;
                        }
                    });
                }
            });
            MiniPeer { addr, seen, wire, conns, shutdown }
        }

        /// Close every accepted socket — what a peer restart looks like
        /// from the pool's side.
        fn kill_conns(&self) {
            for c in self.conns.lock().unwrap().drain(..) {
                let _ = c.shutdown(Shutdown::Both);
            }
            // let the FINs land so staleness is observable at the next
            // checkout peek (loopback: effectively immediate; the sleep
            // absorbs scheduler noise on loaded CI runners)
            std::thread::sleep(Duration::from_millis(50));
        }

        fn seen_modes(&self) -> Vec<WireMode> {
            self.seen.lock().unwrap().clone()
        }
    }

    impl Drop for MiniPeer {
        fn drop(&mut self) {
            self.shutdown.store(true, Ordering::SeqCst);
            let _ = dial(&self.addr, Duration::from_millis(200));
        }
    }

    fn counter(m: &Registry, name: &str) -> u64 {
        m.counter(name).load(Ordering::Relaxed)
    }

    fn tensor_params() -> Payload {
        let mut p = Payload::default();
        let ph = p.stash_mat(Mat::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2));
        p.value = obj([("emb", ph)]);
        p
    }

    #[test]
    fn reuses_one_connection_across_calls() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        for _ in 0..5 {
            let body = pool.call(&peer.addr, "echo", &tensor_params(), None).unwrap();
            assert_eq!(body.mat("emb").unwrap().unwrap().shape(), (2, 2));
        }
        assert_eq!(counter(&metrics, "pool.dials"), 1, "N calls must not mean N dials");
        assert_eq!(counter(&metrics, "pool.hits"), 4);
        assert_eq!(counter(&metrics, "pool.retries"), 0);
        assert_eq!(counter(&metrics, "pool.in_flight"), 0, "gauge must return to zero");
        assert_eq!(pool.idle_conns(&peer.addr), 1);
        // every request rode the once-negotiated binary wire
        assert!(peer.seen_modes().iter().all(|&m| m == WireMode::Binary));
        assert_eq!(counter(&metrics, "wire.json_fallbacks"), 0);
    }

    #[test]
    fn peer_restart_forces_redial_and_renegotiation() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        pool.call(&peer.addr, "echo", &tensor_params(), None).unwrap();
        // "restart": all sockets die and the reborn peer is JSON-forced
        peer.kill_conns();
        *peer.wire.lock().unwrap() = WireMode::Json;
        pool.call(&peer.addr, "echo", &tensor_params(), None).unwrap();
        // the second call must have re-dialed and re-negotiated (hello
        // again — never send v2 blind on a fresh socket): the restarted
        // peer saw a v1 frame
        assert_eq!(peer.seen_modes(), vec![WireMode::Binary, WireMode::Json]);
        assert_eq!(counter(&metrics, "pool.dials"), 2);
        assert_eq!(counter(&metrics, "wire.json_fallbacks"), 1);
        assert!(counter(&metrics, "pool.evictions") >= 1);
    }

    #[test]
    fn call_once_recovers_stale_conns_via_peek_not_retry() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        pool.call(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap();
        // the parked conn dies; a non-idempotent call must still succeed —
        // the checkout-time staleness peek evicts the dead socket before
        // anything is written, so no mid-exchange retry is ever needed
        peer.kill_conns();
        let (_, mode) = pool
            .call_once(&peer.addr, "echo", &Payload::json(Value::Null), None)
            .unwrap();
        assert_eq!(mode, WireMode::Binary);
        assert_eq!(counter(&metrics, "pool.dials"), 2);
        assert_eq!(counter(&metrics, "pool.retries"), 0, "call_once must never re-send");
    }

    #[test]
    fn idle_timeout_evicts_parked_connections() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let cfg = PoolConfig { max_idle_per_peer: 4, idle_timeout_ms: 25 };
        let pool = ConnPool::new(cfg, WireMode::Binary, Some(metrics.clone()));
        pool.call(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap();
        assert_eq!(pool.idle_conns(&peer.addr), 1);
        std::thread::sleep(Duration::from_millis(80));
        pool.call(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap();
        assert_eq!(counter(&metrics, "pool.dials"), 2, "aged-out conn must not be reused");
        assert!(counter(&metrics, "pool.evictions") >= 1);
        assert_eq!(counter(&metrics, "pool.hits"), 0);
    }

    #[test]
    fn max_idle_zero_disables_reuse() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let cfg = PoolConfig { max_idle_per_peer: 0, idle_timeout_ms: 30_000 };
        let pool = ConnPool::new(cfg, WireMode::Binary, Some(metrics.clone()));
        for _ in 0..3 {
            pool.call(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap();
        }
        assert_eq!(counter(&metrics, "pool.dials"), 3);
        assert_eq!(counter(&metrics, "pool.hits"), 0);
        assert_eq!(pool.idle_conns(&peer.addr), 0);
    }

    #[test]
    fn concurrent_checkout_exhausts_then_caps_idle() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let cfg = PoolConfig { max_idle_per_peer: 2, idle_timeout_ms: 30_000 };
        let pool = ConnPool::new(cfg, WireMode::Binary, Some(metrics.clone()));
        // 6 simultaneous holders: the pool must dial past its idle cap
        // (it bounds parked sockets, not in-flight concurrency) ...
        let conns: Vec<PooledConn> = std::thread::scope(|s| {
            let handles: Vec<_> =
                (0..6).map(|_| s.spawn(|| pool.checkout(&peer.addr).unwrap())).collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(counter(&metrics, "pool.dials"), 6, "exhausted pool must dial");
        // ... and keep only max_idle of them at checkin
        for c in conns {
            pool.checkin(&peer.addr, c);
        }
        assert_eq!(pool.idle_conns(&peer.addr), 2);
        assert_eq!(counter(&metrics, "pool.evictions"), 4);
        // the parked pair still serves calls
        pool.call(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap();
        assert_eq!(counter(&metrics, "pool.hits"), 1);
    }

    #[test]
    fn invalidate_drops_idle_and_blocks_stale_checkin() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        let held = pool.checkout(&peer.addr).unwrap();
        pool.call(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap();
        assert_eq!(pool.idle_conns(&peer.addr), 1);
        pool.invalidate(&peer.addr);
        assert_eq!(pool.idle_conns(&peer.addr), 0);
        // a conn checked out before the invalidation must not re-enter
        pool.checkin(&peer.addr, held);
        assert_eq!(pool.idle_conns(&peer.addr), 0);
        assert!(counter(&metrics, "pool.evictions") >= 2);
    }

    /// The ISSUE 5 satellite pin: keepalive probes are invisible to
    /// `pool.dials` (and to the pool's contents), so the
    /// dials-once-per-worker scatter invariant survives health checking.
    #[test]
    fn probe_peer_counts_keepalives_not_dials() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let pool =
            ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        // no parked connection: the probe dials, but only the keepalive
        // counter moves, and nothing is parked or negotiated
        assert!(pool.probe_peer(&peer.addr, Duration::from_millis(500)));
        assert_eq!(counter(&metrics, "pool.keepalive_probes"), 1);
        assert_eq!(counter(&metrics, "pool.dials"), 0, "probes must not count as dials");
        assert_eq!(pool.idle_conns(&peer.addr), 0, "probes must not park connections");
        // with a healthy parked connection the probe answers by peek
        // (no dial at all), but still counts as a probe
        pool.call(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap();
        assert!(pool.probe_peer(&peer.addr, Duration::from_millis(500)));
        assert_eq!(counter(&metrics, "pool.keepalive_probes"), 2);
        // a dead peer fails the probe without touching pool.dials
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        assert!(!pool.probe_peer(&dead, Duration::from_millis(300)));
        assert_eq!(counter(&metrics, "pool.keepalive_probes"), 3);
        assert_eq!(counter(&metrics, "pool.dials"), 1, "only the real call dialed");
    }

    #[test]
    fn backoff_window_grows_caps_and_jitters_deterministically() {
        for streak in 1..=12u32 {
            let raw = BACKOFF_BASE_MS
                .saturating_mul(1u64 << (streak - 1).min(10))
                .min(BACKOFF_CAP_MS);
            let w = backoff_wait_ms("10.0.0.1:7001", streak);
            assert!(
                w >= raw / 2 && w <= raw,
                "streak {streak}: wait {w}ms outside [{}, {raw}]",
                raw / 2
            );
            assert_eq!(
                w,
                backoff_wait_ms("10.0.0.1:7001", streak),
                "jitter must be deterministic per (addr, streak)"
            );
        }
        // different peers land on different points of the window
        assert!(backoff_wait_ms("a:1", 40) <= BACKOFF_CAP_MS);
    }

    /// The ISSUE 7 satellite pin: a dead peer's redials open a growing
    /// wait window (counted under `pool.backoff_ms`) instead of
    /// hot-looping connect attempts, and the very first dial never waits.
    #[test]
    fn dead_peer_redials_back_off_instead_of_hot_looping() {
        // grab a port, then free it: connects get an instant refusal,
        // so any pool.backoff_ms growth is from the backoff sleep alone
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()))
            .with_timeouts(Duration::from_millis(200), Duration::from_millis(200));
        pool.call(&addr, "echo", &Payload::json(Value::Null), None).unwrap_err();
        assert_eq!(counter(&metrics, "pool.backoff_ms"), 0, "first dial must not back off");
        pool.call(&addr, "echo", &Payload::json(Value::Null), None).unwrap_err();
        let after_second = counter(&metrics, "pool.backoff_ms");
        // the counted wait is the window minus time already elapsed since
        // the failure, so allow a few ms of rounding below the jitter floor
        assert!(
            after_second >= BACKOFF_BASE_MS / 2 - 5,
            "second dial should wait out ~the base window, waited {after_second}ms"
        );
        pool.call(&addr, "echo", &Payload::json(Value::Null), None).unwrap_err();
        let after_third = counter(&metrics, "pool.backoff_ms");
        assert!(after_third > after_second, "the window must grow with the streak");
        assert!(after_third <= 3 * BACKOFF_CAP_MS, "windows must stay capped");
    }

    #[test]
    fn dial_failure_propagates_as_io() {
        // grab a port, then free it: nothing listens there
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, None)
            .with_timeouts(Duration::from_millis(300), Duration::from_millis(300));
        let err = pool.call(&addr, "echo", &Payload::json(Value::Null), None).unwrap_err();
        assert!(matches!(err, RpcError::Io(_)), "{err}");
        assert!(matches!(
            dial("not-an-address", Duration::from_millis(100)),
            Err(RpcError::Malformed(_))
        ));
    }

    use std::sync::atomic::AtomicUsize;

    /// Real `serve_conn` peer with mux granted — what an upgraded
    /// `AlServer` looks like to the pool. Counts accepted sockets so
    /// tests can pin connection reuse.
    struct MuxPeer {
        addr: String,
        accepted: Arc<AtomicUsize>,
        shutdown: Arc<AtomicBool>,
    }

    impl MuxPeer {
        fn start() -> MuxPeer {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            let accepted = Arc::new(AtomicUsize::new(0));
            let shutdown = Arc::new(AtomicBool::new(false));
            let (acc, stop) = (accepted.clone(), shutdown.clone());
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(mut stream) = stream else { continue };
                    acc.fetch_add(1, Ordering::SeqCst);
                    let stop = stop.clone();
                    std::thread::spawn(move || {
                        let metrics = Registry::new();
                        rpc::serve_conn(
                            &mut stream,
                            "test-mux-peer",
                            &stop,
                            &metrics,
                            None,
                            WireMode::Binary,
                            |method, params, _mode| match method {
                                "hello" => Ok(Payload::json(wire::hello_reply(
                                    &params.value,
                                    WireMode::Binary,
                                    true,
                                ))),
                                "echo" => Ok(params.to_payload()),
                                "slow" => {
                                    let ms =
                                        params.value.get("ms").and_then(Value::as_i64).unwrap_or(0);
                                    std::thread::sleep(Duration::from_millis(ms as u64));
                                    Ok(params.to_payload())
                                }
                                other => Err(format!("unknown method '{other}'")),
                            },
                        );
                    });
                }
            });
            MuxPeer { addr, accepted, shutdown }
        }

        fn sockets(&self) -> usize {
            self.accepted.load(Ordering::SeqCst)
        }
    }

    impl Drop for MuxPeer {
        fn drop(&mut self) {
            self.shutdown.store(true, Ordering::SeqCst);
            let _ = dial(&self.addr, Duration::from_millis(200));
        }
    }

    /// The PR 8 socket pin at the pool layer: a herd of concurrent
    /// callers to one mux peer shares a single connection — including
    /// the thundering first contact, which must coalesce into one dial.
    #[test]
    fn concurrent_mux_calls_share_one_socket() {
        let peer = MuxPeer::start();
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        std::thread::scope(|s| {
            for i in 0..8i64 {
                let (pool, addr) = (&pool, &peer.addr);
                s.spawn(move || {
                    for j in 0..4i64 {
                        let v = Value::from(i * 10 + j);
                        let body = pool
                            .call(addr, "echo", &Payload::json(v), Some(Duration::from_secs(10)))
                            .expect("mux echo");
                        assert_eq!(body.value.as_i64(), Some(i * 10 + j), "demux crossed replies");
                    }
                });
            }
        });
        assert_eq!(peer.sockets(), 1, "32 concurrent calls must share one socket");
        assert_eq!(counter(&metrics, "pool.dials"), 1, "first-contact herd must coalesce");
        assert_eq!(counter(&metrics, "pool.hits"), 31);
        assert_eq!(counter(&metrics, "mux.in_flight"), 0, "gauge must return to zero");
        assert_eq!(counter(&metrics, "mux.frames"), 32);
        assert_eq!(counter(&metrics, "pool.retries"), 0);
    }

    /// Replies come back out of request order (slow request first, fast
    /// second) and each lands in its own waiter — the fast caller never
    /// queues behind the slow one's reply.
    #[test]
    fn mux_demuxes_out_of_order_replies_on_one_socket() {
        let peer = MuxPeer::start();
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        // warm the shared conn so both threads find it installed
        pool.call(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap();
        let (fast_elapsed, slow_elapsed) = std::thread::scope(|s| {
            let slow = s.spawn(|| {
                let t0 = Instant::now();
                let body = pool
                    .call(
                        &peer.addr,
                        "slow",
                        &Payload::json(obj([("ms", Value::from(400))])),
                        Some(Duration::from_secs(10)),
                    )
                    .expect("slow call");
                assert_eq!(body.value.get("ms").and_then(Value::as_i64), Some(400));
                t0.elapsed()
            });
            // let the slow request get onto the wire first
            std::thread::sleep(Duration::from_millis(60));
            let t0 = Instant::now();
            let body = pool
                .call(
                    &peer.addr,
                    "echo",
                    &Payload::json(Value::from(42)),
                    Some(Duration::from_secs(10)),
                )
                .expect("fast call");
            assert_eq!(body.value.as_i64(), Some(42));
            (t0.elapsed(), slow.join().unwrap())
        });
        assert!(
            fast_elapsed < Duration::from_millis(300),
            "fast reply waited behind slow: {fast_elapsed:?}"
        );
        assert!(slow_elapsed >= Duration::from_millis(400));
        assert_eq!(peer.sockets(), 1, "both calls must share the socket");
        assert_eq!(counter(&metrics, "pool.dials"), 1);
    }

    /// Peer that grants mux, then poisons the stream with a reply whose
    /// id was never requested; later connections behave.
    fn start_rogue_mux_peer() -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let mut first = true;
            for stream in listener.incoming() {
                let Ok(mut stream) = stream else { continue };
                let rogue = std::mem::replace(&mut first, false);
                std::thread::spawn(move || loop {
                    let Ok(buf) = rpc::read_frame(&mut stream) else { return };
                    let Ok(req) = rpc::decode_request_frame(buf) else { return };
                    if req.method == "hello" {
                        let reply = Payload::json(wire::hello_reply(
                            &req.params.value,
                            WireMode::Binary,
                            true,
                        ));
                        if rpc::send_result_wire(&mut stream, req.id, &reply, req.mode, None)
                            .is_err()
                        {
                            return;
                        }
                    } else if rogue {
                        // a reply nobody asked for, then hang up
                        let reply = Payload::json(Value::from("surprise"));
                        let _ =
                            rpc::send_result_wire(&mut stream, 0xdead_beef, &reply, req.mode, None);
                        return;
                    } else if rpc::send_result_wire(
                        &mut stream,
                        req.id,
                        &req.params.to_payload(),
                        req.mode,
                        None,
                    )
                    .is_err()
                    {
                        return;
                    }
                });
            }
        });
        addr
    }

    /// A reply carrying an id that was never issued is protocol desync:
    /// the connection dies with a diagnostic naming the id, and the next
    /// call recovers on a fresh dial.
    #[test]
    fn unknown_reply_id_kills_mux_conn_then_recovers() {
        let addr = start_rogue_mux_peer();
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        let err = pool
            .call(&addr, "echo", &Payload::json(Value::Null), Some(Duration::from_secs(5)))
            .unwrap_err();
        assert!(err.to_string().contains("unknown id"), "got: {err}");
        let body = pool
            .call(&addr, "echo", &Payload::json(Value::from(7)), Some(Duration::from_secs(5)))
            .expect("fresh conn must recover");
        assert_eq!(body.value.as_i64(), Some(7));
        assert_eq!(counter(&metrics, "pool.dials"), 2);
    }

    /// A deadline abandons only its own request: the late reply is
    /// dropped on arrival and the shared connection keeps serving.
    #[test]
    fn mux_deadline_abandons_request_and_drops_late_reply() {
        let peer = MuxPeer::start();
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        let err = pool
            .call(
                &peer.addr,
                "slow",
                &Payload::json(obj([("ms", Value::from(400))])),
                Some(Duration::from_millis(60)),
            )
            .unwrap_err();
        assert!(err.to_string().contains("deadline"), "got: {err}");
        assert_eq!(counter(&metrics, "mux.in_flight"), 0, "abandon must release the slot");
        // the late reply lands while this call is in flight; it must be
        // discarded silently, not kill the conn as an unknown id
        let body = pool
            .call(
                &peer.addr,
                "slow",
                &Payload::json(obj([("ms", Value::from(500))])),
                Some(Duration::from_secs(10)),
            )
            .expect("conn must survive the late reply");
        assert_eq!(body.value.get("ms").and_then(Value::as_i64), Some(500));
        assert_eq!(peer.sockets(), 1, "no redial: the timed-out conn stays usable");
        assert_eq!(counter(&metrics, "pool.dials"), 1);
    }

    /// The ISSUE 8 stale-deadline satellite pin: a per-call read
    /// deadline set by one call must not be inherited by the next
    /// checkout of the same parked connection.
    #[test]
    fn checkin_clears_per_call_read_deadline() {
        let peer = MiniPeer::start(WireMode::Binary);
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, None).with_mux(false);
        // a call with a tight per-call deadline parks its conn afterwards
        pool.call(&peer.addr, "echo", &Payload::json(Value::Null), Some(Duration::from_millis(40)))
            .unwrap();
        // drive the parked conn directly (no pool-side timeout handling):
        // a deadline-less exchange against a 150ms-slow reply must not
        // inherit the 40ms deadline
        let mut conn = pool.checkout(&peer.addr).unwrap();
        assert!(conn.is_reused(), "test needs the parked conn, not a fresh dial");
        let id = conn.next_id;
        conn.next_id += 1;
        rpc::send_request_wire(
            &mut conn.stream,
            id,
            "slow",
            &Payload::json(obj([("ms", Value::from(150))])),
            conn.mode,
            None,
        )
        .unwrap();
        let body = rpc::recv_response_body(&mut conn.stream, id, None)
            .expect("parked conn inherited the previous call's 40ms read deadline");
        assert_eq!(body.value.get("ms").and_then(Value::as_i64), Some(150));
    }

    /// Old/classic peers (no mux echo in `hello`) fall back to the
    /// one-RPC-per-connection path: the refusing dial's conn is used
    /// directly, remembered as refused, and `start` reports `None`.
    #[test]
    fn old_peer_mux_refusal_falls_back_to_classic_path() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        assert_eq!(pool.peer_muxes(&peer.addr), None, "unknown before first contact");
        let body = pool.call(&peer.addr, "echo", &Payload::json(Value::from(1)), None).unwrap();
        assert_eq!(body.value.as_i64(), Some(1));
        assert_eq!(pool.peer_muxes(&peer.addr), Some(false));
        // the refusal is sticky: no re-ask, the donated conn is reused
        pool.call(&peer.addr, "echo", &Payload::json(Value::from(2)), None).unwrap();
        assert_eq!(counter(&metrics, "pool.dials"), 1, "refusal must not cost extra dials");
        assert_eq!(counter(&metrics, "pool.hits"), 1);
        assert!(
            pool.start(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap().is_none(),
            "start must report the classic path for a refusing peer"
        );
        // a mux-disabled pool never even asks
        let pool_off = ConnPool::new(PoolConfig::default(), WireMode::Binary, None).with_mux(false);
        assert_eq!(pool_off.peer_muxes(&peer.addr), Some(false));
    }

    /// `start`/`wait` against a mux peer: fire several requests from one
    /// thread, then await them in any order.
    #[test]
    fn start_then_wait_completes_out_of_await_order() {
        let peer = MuxPeer::start();
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        let calls: Vec<PendingCall> = (0..5i64)
            .map(|i| {
                pool.start(
                    &peer.addr,
                    "echo",
                    &Payload::json(Value::from(i)),
                    Some(Duration::from_secs(10)),
                )
                .expect("start")
                .expect("MuxPeer must grant mux")
            })
            .collect();
        // await in reverse: completion order must not matter
        for (i, call) in calls.into_iter().enumerate().rev() {
            let body = pool.wait(call).expect("wait");
            assert_eq!(body.value.as_i64(), Some(i as i64));
        }
        assert_eq!(peer.sockets(), 1);
        assert_eq!(counter(&metrics, "mux.in_flight"), 0);
        assert_eq!(pool.peer_muxes(&peer.addr), Some(true));
        // dropping an unawaited call abandons it without killing the conn
        let dangling = pool
            .start(&peer.addr, "echo", &Payload::json(Value::Null), None)
            .unwrap()
            .unwrap();
        drop(dangling);
        pool.call(&peer.addr, "echo", &Payload::json(Value::from(9)), None).unwrap();
        assert_eq!(counter(&metrics, "mux.in_flight"), 0);
        assert_eq!(peer.sockets(), 1);
    }
}

//! Per-peer persistent connection pool for the RPC stack (DESIGN.md
//! §Wire).
//!
//! PR 2 made the payloads cheap; this layer makes the *calls* cheap. The
//! small-call-heavy paths (agent arm rounds, shard probes, status polls)
//! previously paid a fresh `TcpStream::connect` — and, for the
//! coordinator, an optimistic-send-or-fallback wire dance — on every RPC.
//! A [`ConnPool`] instead keeps up to `max_idle_per_peer` negotiated
//! connections parked per peer:
//!
//! * **Negotiation happens once per connection.** A binary-preferring
//!   pool sends one v1 `hello {wire, version}` on each fresh dial; the
//!   agreed [`WireMode`] rides with the connection for its lifetime, so
//!   no call ever sends v2 frames blind. A peer that refuses binary (or
//!   predates `hello`) leaves the connection on v1 and counts one
//!   `wire.json_fallbacks`.
//! * **Stale connections are detected, evicted, and re-dialed.** A
//!   checkout probes the parked socket with a non-blocking peek (a
//!   restarted peer shows EOF); a call that dies mid-flight on a *reused*
//!   connection with a dead-socket error is retried exactly once on a
//!   fresh dial. Errors on fresh connections propagate unchanged, so the
//!   cluster's mark-dead / re-dispatch semantics are preserved
//!   bit-for-bit.
//! * **Idle hygiene.** Connections parked longer than `idle_timeout_ms`
//!   are closed at the next checkout; `invalidate` drops a peer's whole
//!   idle set (worker re-registration, observed death).
//! * **Redial backoff.** Consecutive dial *failures* to a peer open a
//!   capped, exponentially growing wait window (25 ms doubling to
//!   400 ms, deterministically jittered per `(addr, streak)` so a fleet
//!   of clients never thunders in phase). A dial inside an open window
//!   sleeps out the remainder first — a dead peer cannot be hot-loop
//!   dialed during recovery — while the first dial after any success is
//!   always immediate, so the happy path pays nothing.
//!
//! Metrics (when constructed with a registry): `pool.hits`, `pool.dials`,
//! `pool.evictions`, `pool.retries`, `pool.keepalive_probes`,
//! `pool.backoff_ms` counters and the `pool.in_flight` gauge. Keepalive
//! probes (`probe_peer`) never count as dials: the dials-per-scatter pin
//! stays meaningful with background health checking on.

use std::collections::HashMap;
use std::io::ErrorKind;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::json::{Map, Value};
use crate::metrics::Registry;
use crate::util::fnv1a;

use super::rpc::{self, RpcError};
use super::wire::{self, Body, Payload, WireMode};

/// `[server.pool]` knobs (DESIGN.md §Wire).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolConfig {
    /// Idle connections kept per peer. `0` disables reuse entirely —
    /// every call dials, negotiates (one `hello` round trip), and
    /// closes. Kept as an escape hatch and for parity testing; note it
    /// is *costlier* than the pre-pool coordinator, which sent
    /// optimistically without a negotiation round trip.
    pub max_idle_per_peer: usize,
    /// Idle connections parked longer than this are closed at the next
    /// checkout.
    pub idle_timeout_ms: u64,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { max_idle_per_peer: 4, idle_timeout_ms: 30_000 }
    }
}

/// Default per-candidate-address connect timeout.
pub const DIAL_TIMEOUT: Duration = Duration::from_secs(5);
/// Read deadline for the dial-time `hello`: a peer that accepts TCP but
/// never answers must fail the dial, not hang it.
const HELLO_TIMEOUT: Duration = Duration::from_secs(10);

/// Resolve `addr` ("host:port") and connect, TCP_NODELAY set — the
/// single dialing path shared by pooled RPCs and the servers' shutdown
/// wakeups, so liveness behavior cannot diverge between "real" and
/// bookkeeping connections. Every resolved candidate address is tried
/// (an instant refusal on `::1` falls through to `127.0.0.1`), but
/// `timeout` bounds the *total* time across all of them, so a
/// black-holed multi-address peer still fails within one timeout —
/// dead-peer detection latency matches a single-address dial.
pub fn dial(addr: &str, timeout: Duration) -> Result<TcpStream, RpcError> {
    let deadline = Instant::now() + timeout;
    let mut last: Option<std::io::Error> = None;
    for sock in addr
        .to_socket_addrs()
        .map_err(|e| RpcError::Malformed(format!("bad peer address '{addr}': {e}")))?
    {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            // deadline burned before this attempt (e.g. slow DNS inside
            // to_socket_addrs, or earlier candidates): that's a timeout,
            // not a bad address
            last = last.or_else(|| {
                Some(std::io::Error::new(
                    ErrorKind::TimedOut,
                    format!("dial deadline exhausted before connecting to '{addr}'"),
                ))
            });
            break;
        }
        match TcpStream::connect_timeout(&sock, left) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) => last = Some(e),
        }
    }
    Err(match last {
        Some(e) => RpcError::Io(e),
        None => RpcError::Malformed(format!("address '{addr}' resolved to nothing")),
    })
}

/// A connection checked out of the pool. Return it with
/// [`ConnPool::checkin`] after a successful exchange; drop it on failure
/// (the socket state is unknown mid-protocol).
pub struct PooledConn {
    stream: TcpStream,
    /// Wire encoding negotiated once for this connection's lifetime.
    mode: WireMode,
    next_id: u64,
    /// Came from the idle set (vs freshly dialed) — drives the
    /// retry-once policy.
    reused: bool,
    generation: u64,
}

impl PooledConn {
    pub fn mode(&self) -> WireMode {
        self.mode
    }

    pub fn is_reused(&self) -> bool {
        self.reused
    }
}

struct IdleConn {
    stream: TcpStream,
    mode: WireMode,
    next_id: u64,
    parked_at: Instant,
}

#[derive(Default)]
struct PeerState {
    idle: Vec<IdleConn>,
    /// Bumped by `invalidate`; a checkout from an older generation is
    /// dropped at checkin instead of being pooled.
    generation: u64,
    /// Consecutive dial failures since the last successful dial — the
    /// redial-backoff exponent. Only TCP connect failures count;
    /// negotiation errors have their own bounded `hello` deadline.
    fail_streak: u32,
    /// When the streak's latest failure happened; the backoff window is
    /// measured from here, so time already spent elsewhere (e.g. the
    /// failed dial's own timeout) is credited against the wait.
    last_fail: Option<Instant>,
}

/// Backoff floor: the window after the first failed dial.
const BACKOFF_BASE_MS: u64 = 25;
/// Backoff ceiling: windows stop growing here so a long-dead peer's
/// eventual recovery is noticed within half a second.
const BACKOFF_CAP_MS: u64 = 400;

/// The jittered wait window before dial attempt `streak + 1`:
/// `min(25ms * 2^(streak-1), 400ms)`, scaled into `[1/2, 1]` of itself by
/// a hash of `(addr, streak)`. Deterministic on purpose — no RNG state,
/// reproducible in tests — while still decorrelating different clients
/// (different hash inputs) so they cannot redial a recovering peer in
/// lockstep.
fn backoff_wait_ms(addr: &str, streak: u32) -> u64 {
    debug_assert!(streak >= 1);
    let raw = BACKOFF_BASE_MS
        .saturating_mul(1u64 << (streak.saturating_sub(1)).min(10))
        .min(BACKOFF_CAP_MS);
    let h = fnv1a(addr.as_bytes()) ^ (streak as u64);
    // factor in [1/2, 1): wait = raw/2 + raw/2 * (h % 1024)/1024
    raw / 2 + (raw / 2).saturating_mul(h % 1024) / 1024
}

/// Thread-safe per-peer pool of persistent, wire-negotiated connections.
pub struct ConnPool {
    cfg: PoolConfig,
    /// Wire encoding this process asks peers for (`server.wire`).
    prefer: WireMode,
    dial_timeout: Duration,
    hello_timeout: Duration,
    metrics: Option<Arc<Registry>>,
    /// When set, span subtrees piggybacked on replies are adopted into
    /// this tracer (the coordinator's end-to-end tree assembly).
    tracer: Option<Arc<crate::trace::Tracer>>,
    peers: Mutex<HashMap<String, PeerState>>,
}

impl ConnPool {
    pub fn new(cfg: PoolConfig, prefer: WireMode, metrics: Option<Arc<Registry>>) -> ConnPool {
        ConnPool {
            cfg,
            prefer,
            dial_timeout: DIAL_TIMEOUT,
            hello_timeout: HELLO_TIMEOUT,
            metrics,
            tracer: None,
            peers: Mutex::new(HashMap::new()),
        }
    }

    /// Override both the connect and the `hello` deadlines (the client's
    /// `connect_timeout` surface).
    pub fn with_timeouts(mut self, dial: Duration, hello: Duration) -> ConnPool {
        self.dial_timeout = dial;
        self.hello_timeout = hello;
        self
    }

    /// Adopt remote span subtrees from replies into `tracer`.
    pub fn with_tracer(mut self, tracer: Arc<crate::trace::Tracer>) -> ConnPool {
        self.tracer = Some(tracer);
        self
    }

    fn count(&self, name: &str, n: u64) {
        if let Some(m) = &self.metrics {
            m.counter(name).fetch_add(n, Ordering::Relaxed);
        }
    }

    fn registry(&self) -> Option<&Registry> {
        self.metrics.as_deref()
    }

    /// Idle connections currently parked for `addr` (tests/benches).
    pub fn idle_conns(&self, addr: &str) -> usize {
        self.peers.lock().unwrap().get(addr).map(|p| p.idle.len()).unwrap_or(0)
    }

    /// Drop every idle connection to `addr` and mark in-flight ones as
    /// non-poolable — for peers known to have restarted or died (worker
    /// re-registration, observed transport failure).
    pub fn invalidate(&self, addr: &str) {
        let mut peers = self.peers.lock().unwrap();
        if let Some(p) = peers.get_mut(addr) {
            p.generation += 1;
            if !p.idle.is_empty() {
                self.count("pool.evictions", p.idle.len() as u64);
                p.idle.clear();
            }
        }
    }

    /// Background keepalive/health probe: is `addr` alive right now? A
    /// healthy parked idle connection answers for free (non-blocking
    /// peek); otherwise one bounded dial is made and immediately closed.
    /// Probe dials count under `pool.keepalive_probes` — **never**
    /// `pool.dials`, so health checking cannot distort the
    /// dials-per-scatter invariant the cluster tests pin — and they
    /// neither negotiate nor park, so a probe can never change any
    /// connection's wire mode or the pool's contents. The coordinator's
    /// membership sweep uses this to evict a dead worker before a query
    /// pays the scatter dial timeout (DESIGN.md §Cluster).
    pub fn probe_peer(&self, addr: &str, timeout: Duration) -> bool {
        self.count("pool.keepalive_probes", 1);
        {
            let peers = self.peers.lock().unwrap();
            if let Some(p) = peers.get(addr) {
                if p.idle.iter().any(|c| !stream_is_stale(&c.stream)) {
                    return true;
                }
            }
        }
        dial(addr, timeout).is_ok()
    }

    /// Check out a connection to `addr`: the freshest live idle one, or a
    /// fresh dial (+ one-time wire negotiation) when none survives the
    /// idle-timeout and staleness checks.
    pub fn checkout(&self, addr: &str) -> Result<PooledConn, RpcError> {
        let idle_timeout = Duration::from_millis(self.cfg.idle_timeout_ms);
        loop {
            let (cand, generation) = {
                let mut peers = self.peers.lock().unwrap();
                let p = peers.entry(addr.to_string()).or_default();
                // age out from the oldest end first
                let before = p.idle.len();
                p.idle.retain(|c| c.parked_at.elapsed() <= idle_timeout);
                let aged = before - p.idle.len();
                if aged > 0 {
                    self.count("pool.evictions", aged as u64);
                }
                (p.idle.pop(), p.generation)
            };
            match cand {
                Some(c) => {
                    if stream_is_stale(&c.stream) {
                        // a restarted/dead peer: close and try the next
                        self.count("pool.evictions", 1);
                        continue;
                    }
                    self.count("pool.hits", 1);
                    return Ok(PooledConn {
                        stream: c.stream,
                        mode: c.mode,
                        next_id: c.next_id,
                        reused: true,
                        generation,
                    });
                }
                None => return self.dial_negotiated(addr, generation),
            }
        }
    }

    /// Park a connection for reuse. Dropped instead when pooling is off,
    /// the peer's idle set is full, or the peer was invalidated after
    /// this connection was checked out.
    pub fn checkin(&self, addr: &str, conn: PooledConn) {
        if self.cfg.max_idle_per_peer == 0 {
            return; // per-call mode: close by drop, nothing to count
        }
        let mut peers = self.peers.lock().unwrap();
        let p = peers.entry(addr.to_string()).or_default();
        if conn.generation != p.generation || p.idle.len() >= self.cfg.max_idle_per_peer {
            self.count("pool.evictions", 1);
            return;
        }
        p.idle.push(IdleConn {
            stream: conn.stream,
            mode: conn.mode,
            next_id: conn.next_id,
            parked_at: Instant::now(),
        });
    }

    /// Dial + negotiate one fresh connection. The `hello` rides the new
    /// socket as v1 JSON (any peer can answer); a refusal or a pre-v2
    /// `unknown method` error leaves the connection on the JSON wire.
    fn dial_negotiated(&self, addr: &str, generation: u64) -> Result<PooledConn, RpcError> {
        self.backoff_before_dial(addr);
        let mut stream = match dial(addr, self.dial_timeout) {
            Ok(s) => {
                self.note_dial_outcome(addr, true);
                s
            }
            Err(e) => {
                self.note_dial_outcome(addr, false);
                return Err(e);
            }
        };
        let mut next_id = 1u64;
        let mut mode = WireMode::Json;
        if self.prefer == WireMode::Binary {
            stream.set_read_timeout(Some(self.hello_timeout)).ok();
            let mut p = Map::new();
            p.insert("wire", Value::from(WireMode::Binary.as_str()));
            p.insert("version", Value::from(wire::WIRE_VERSION as u64));
            let id = next_id;
            next_id += 1;
            rpc::send_request_wire(
                &mut stream,
                id,
                "hello",
                &Payload::json(Value::Object(p)),
                WireMode::Json,
                self.registry(),
            )?;
            match rpc::recv_response_body(&mut stream, id, self.registry()) {
                Ok(b) => {
                    if b.value.get("wire").and_then(Value::as_str) == Some("binary") {
                        mode = WireMode::Binary;
                    }
                }
                // pre-v2 peer: no `hello` method — stay on JSON; any
                // other remote error is a real failure, not version skew
                Err(RpcError::Remote(msg)) if msg.contains("unknown method") => {}
                Err(e) => return Err(e),
            }
            stream.set_read_timeout(None).ok();
            if mode == WireMode::Json {
                // the peer cannot (or will not) speak v2: every call on
                // this connection now pays the slow JSON plane
                self.count("wire.json_fallbacks", 1);
            }
        }
        self.count("pool.dials", 1);
        Ok(PooledConn { stream, mode, next_id, reused: false, generation })
    }

    /// One blocking request/response exchange over a pooled connection,
    /// for an **idempotent** (safely re-sendable) method. Tensor payloads
    /// encode per the connection's negotiated mode (raw sections on v2,
    /// inlined JSON on v1). A dead-socket failure on a *reused*
    /// connection is retried once on a fresh dial; all other failures —
    /// including any failure of the fresh attempt — propagate, so
    /// callers' liveness handling sees exactly what a per-call dial
    /// would have seen.
    pub fn call(
        &self,
        addr: &str,
        method: &str,
        params: &Payload,
        read_timeout: Option<Duration>,
    ) -> Result<Body, RpcError> {
        self.call_negotiated(addr, method, params, read_timeout).map(|(b, _)| b)
    }

    /// [`ConnPool::call`], also reporting the connection's negotiated
    /// [`WireMode`] (clients mirror it for mode-sensitive encodes).
    pub fn call_negotiated(
        &self,
        addr: &str,
        method: &str,
        params: &Payload,
        read_timeout: Option<Duration>,
    ) -> Result<(Body, WireMode), RpcError> {
        self.call_gauged(addr, method, params, read_timeout, true)
    }

    /// [`ConnPool::call_negotiated`] for **non-idempotent** methods
    /// (`agent_start`): a parked connection dying mid-exchange is
    /// ambiguous — the server may already be running the request — so it
    /// surfaces as an error instead of being silently re-sent. The
    /// checkout-time staleness peek still rescues the common
    /// already-dead-socket case before anything is written.
    pub fn call_once(
        &self,
        addr: &str,
        method: &str,
        params: &Payload,
        read_timeout: Option<Duration>,
    ) -> Result<(Body, WireMode), RpcError> {
        self.call_gauged(addr, method, params, read_timeout, false)
    }

    fn call_gauged(
        &self,
        addr: &str,
        method: &str,
        params: &Payload,
        read_timeout: Option<Duration>,
        retry_stale: bool,
    ) -> Result<(Body, WireMode), RpcError> {
        let gauge = self.metrics.as_ref().map(|m| m.counter("pool.in_flight"));
        if let Some(g) = &gauge {
            g.fetch_add(1, Ordering::Relaxed);
        }
        let out = self.call_inner(addr, method, params, read_timeout, retry_stale);
        if let Some(g) = &gauge {
            g.fetch_sub(1, Ordering::Relaxed);
        }
        out
    }

    fn call_inner(
        &self,
        addr: &str,
        method: &str,
        params: &Payload,
        read_timeout: Option<Duration>,
        retry_stale: bool,
    ) -> Result<(Body, WireMode), RpcError> {
        let mut conn = self.checkout(addr)?;
        let reused = conn.reused;
        match self.roundtrip(&mut conn, method, params, read_timeout) {
            Ok(body) => {
                let mode = conn.mode;
                self.checkin(addr, conn);
                Ok((body, mode))
            }
            Err(e) if retry_stale && reused && is_dead_socket(&e) => {
                // the parked connection died under us (peer restart, idle
                // close): its siblings are just as old — flush them and
                // run the request once on a fresh dial. A genuinely dead
                // peer fails the dial and surfaces exactly as before.
                drop(conn);
                self.invalidate(addr);
                self.count("pool.retries", 1);
                let mut fresh = self.dial_and_track(addr)?;
                match self.roundtrip(&mut fresh, method, params, read_timeout) {
                    Ok(body) => {
                        let mode = fresh.mode;
                        self.checkin(addr, fresh);
                        Ok((body, mode))
                    }
                    Err(e2) => Err(e2),
                }
            }
            Err(e) => Err(e),
        }
    }

    /// Sleep out whatever remains of the peer's current backoff window.
    /// No-op when the streak is zero (first dial, or any dial after a
    /// success) or when the window already elapsed while the caller was
    /// doing other work. The lock is never held across the sleep.
    fn backoff_before_dial(&self, addr: &str) {
        let wait = {
            let peers = self.peers.lock().unwrap();
            let Some(p) = peers.get(addr) else { return };
            if p.fail_streak == 0 {
                return;
            }
            let Some(last) = p.last_fail else { return };
            Duration::from_millis(backoff_wait_ms(addr, p.fail_streak))
                .saturating_sub(last.elapsed())
        };
        if !wait.is_zero() {
            self.count("pool.backoff_ms", wait.as_millis() as u64);
            std::thread::sleep(wait);
        }
    }

    fn note_dial_outcome(&self, addr: &str, ok: bool) {
        let mut peers = self.peers.lock().unwrap();
        let p = peers.entry(addr.to_string()).or_default();
        if ok {
            p.fail_streak = 0;
            p.last_fail = None;
        } else {
            p.fail_streak = p.fail_streak.saturating_add(1);
            p.last_fail = Some(Instant::now());
        }
    }

    fn dial_and_track(&self, addr: &str) -> Result<PooledConn, RpcError> {
        let generation =
            self.peers.lock().unwrap().entry(addr.to_string()).or_default().generation;
        self.dial_negotiated(addr, generation)
    }

    fn roundtrip(
        &self,
        conn: &mut PooledConn,
        method: &str,
        params: &Payload,
        read_timeout: Option<Duration>,
    ) -> Result<Body, RpcError> {
        conn.stream.set_read_timeout(read_timeout).ok();
        let id = conn.next_id;
        conn.next_id += 1;
        rpc::send_request_wire(&mut conn.stream, id, method, params, conn.mode, self.registry())?;
        rpc::recv_response_traced(&mut conn.stream, id, self.registry(), self.tracer.as_deref())
    }
}

/// Peer-closed detection without consuming stream bytes: a non-blocking
/// peek on a healthy idle connection yields `WouldBlock`; EOF, any other
/// error, or unsolicited bytes (protocol desync) all mean the connection
/// cannot carry another RPC.
fn stream_is_stale(s: &TcpStream) -> bool {
    if s.set_nonblocking(true).is_err() {
        return true;
    }
    let mut probe = [0u8; 1];
    let stale = match s.peek(&mut probe) {
        Ok(_) => true,
        Err(e) if e.kind() == ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = s.set_nonblocking(false);
    stale
}

/// Did this failure come from a socket that died between calls (as a
/// restarted peer's parked connection does)? Timeouts are deliberately
/// excluded: a slow peer must surface as slow, not be retried into
/// double execution.
fn is_dead_socket(e: &RpcError) -> bool {
    match e {
        RpcError::Closed => true,
        RpcError::Io(io) => matches!(
            io.kind(),
            ErrorKind::ConnectionReset
                | ErrorKind::ConnectionAborted
                | ErrorKind::BrokenPipe
                | ErrorKind::UnexpectedEof
                | ErrorKind::NotConnected
        ),
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::value::obj;
    use crate::util::mat::Mat;
    use std::net::{Shutdown, TcpListener};
    use std::sync::atomic::AtomicBool;

    /// Scripted RPC peer: answers `hello` per a flippable wire policy,
    /// echoes any other method, and records each non-hello request's
    /// encoding. Open sockets are tracked so a test can slam them shut
    /// (simulating a peer restart).
    struct MiniPeer {
        addr: String,
        seen: Arc<Mutex<Vec<WireMode>>>,
        wire: Arc<Mutex<WireMode>>,
        conns: Arc<Mutex<Vec<TcpStream>>>,
        shutdown: Arc<AtomicBool>,
    }

    impl MiniPeer {
        fn start(initial_wire: WireMode) -> MiniPeer {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            let seen = Arc::new(Mutex::new(Vec::new()));
            let wire = Arc::new(Mutex::new(initial_wire));
            let conns = Arc::new(Mutex::new(Vec::new()));
            let shutdown = Arc::new(AtomicBool::new(false));
            let (seen2, wire2, conns2, stop) =
                (seen.clone(), wire.clone(), conns.clone(), shutdown.clone());
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(mut stream) = stream else { continue };
                    conns2.lock().unwrap().push(stream.try_clone().unwrap());
                    let (seen, policy) = (seen2.clone(), wire2.clone());
                    std::thread::spawn(move || loop {
                        let Ok(buf) = rpc::read_frame(&mut stream) else { return };
                        let Ok(req) = rpc::decode_request_frame(buf) else { return };
                        let reply = if req.method == "hello" {
                            Payload::json(wire::hello_reply(
                                &req.params.value,
                                *policy.lock().unwrap(),
                            ))
                        } else {
                            seen.lock().unwrap().push(req.mode);
                            req.params.to_payload()
                        };
                        if rpc::send_result_wire(&mut stream, req.id, &reply, req.mode, None)
                            .is_err()
                        {
                            return;
                        }
                    });
                }
            });
            MiniPeer { addr, seen, wire, conns, shutdown }
        }

        /// Close every accepted socket — what a peer restart looks like
        /// from the pool's side.
        fn kill_conns(&self) {
            for c in self.conns.lock().unwrap().drain(..) {
                let _ = c.shutdown(Shutdown::Both);
            }
            // let the FINs land so staleness is observable at the next
            // checkout peek (loopback: effectively immediate; the sleep
            // absorbs scheduler noise on loaded CI runners)
            std::thread::sleep(Duration::from_millis(50));
        }

        fn seen_modes(&self) -> Vec<WireMode> {
            self.seen.lock().unwrap().clone()
        }
    }

    impl Drop for MiniPeer {
        fn drop(&mut self) {
            self.shutdown.store(true, Ordering::SeqCst);
            let _ = dial(&self.addr, Duration::from_millis(200));
        }
    }

    fn counter(m: &Registry, name: &str) -> u64 {
        m.counter(name).load(Ordering::Relaxed)
    }

    fn tensor_params() -> Payload {
        let mut p = Payload::default();
        let ph = p.stash_mat(Mat::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2));
        p.value = obj([("emb", ph)]);
        p
    }

    #[test]
    fn reuses_one_connection_across_calls() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        for _ in 0..5 {
            let body = pool.call(&peer.addr, "echo", &tensor_params(), None).unwrap();
            assert_eq!(body.mat("emb").unwrap().unwrap().shape(), (2, 2));
        }
        assert_eq!(counter(&metrics, "pool.dials"), 1, "N calls must not mean N dials");
        assert_eq!(counter(&metrics, "pool.hits"), 4);
        assert_eq!(counter(&metrics, "pool.retries"), 0);
        assert_eq!(counter(&metrics, "pool.in_flight"), 0, "gauge must return to zero");
        assert_eq!(pool.idle_conns(&peer.addr), 1);
        // every request rode the once-negotiated binary wire
        assert!(peer.seen_modes().iter().all(|&m| m == WireMode::Binary));
        assert_eq!(counter(&metrics, "wire.json_fallbacks"), 0);
    }

    #[test]
    fn peer_restart_forces_redial_and_renegotiation() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        pool.call(&peer.addr, "echo", &tensor_params(), None).unwrap();
        // "restart": all sockets die and the reborn peer is JSON-forced
        peer.kill_conns();
        *peer.wire.lock().unwrap() = WireMode::Json;
        pool.call(&peer.addr, "echo", &tensor_params(), None).unwrap();
        // the second call must have re-dialed and re-negotiated (hello
        // again — never send v2 blind on a fresh socket): the restarted
        // peer saw a v1 frame
        assert_eq!(peer.seen_modes(), vec![WireMode::Binary, WireMode::Json]);
        assert_eq!(counter(&metrics, "pool.dials"), 2);
        assert_eq!(counter(&metrics, "wire.json_fallbacks"), 1);
        assert!(counter(&metrics, "pool.evictions") >= 1);
    }

    #[test]
    fn call_once_recovers_stale_conns_via_peek_not_retry() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        pool.call(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap();
        // the parked conn dies; a non-idempotent call must still succeed —
        // the checkout-time staleness peek evicts the dead socket before
        // anything is written, so no mid-exchange retry is ever needed
        peer.kill_conns();
        let (_, mode) = pool
            .call_once(&peer.addr, "echo", &Payload::json(Value::Null), None)
            .unwrap();
        assert_eq!(mode, WireMode::Binary);
        assert_eq!(counter(&metrics, "pool.dials"), 2);
        assert_eq!(counter(&metrics, "pool.retries"), 0, "call_once must never re-send");
    }

    #[test]
    fn idle_timeout_evicts_parked_connections() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let cfg = PoolConfig { max_idle_per_peer: 4, idle_timeout_ms: 25 };
        let pool = ConnPool::new(cfg, WireMode::Binary, Some(metrics.clone()));
        pool.call(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap();
        assert_eq!(pool.idle_conns(&peer.addr), 1);
        std::thread::sleep(Duration::from_millis(80));
        pool.call(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap();
        assert_eq!(counter(&metrics, "pool.dials"), 2, "aged-out conn must not be reused");
        assert!(counter(&metrics, "pool.evictions") >= 1);
        assert_eq!(counter(&metrics, "pool.hits"), 0);
    }

    #[test]
    fn max_idle_zero_disables_reuse() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let cfg = PoolConfig { max_idle_per_peer: 0, idle_timeout_ms: 30_000 };
        let pool = ConnPool::new(cfg, WireMode::Binary, Some(metrics.clone()));
        for _ in 0..3 {
            pool.call(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap();
        }
        assert_eq!(counter(&metrics, "pool.dials"), 3);
        assert_eq!(counter(&metrics, "pool.hits"), 0);
        assert_eq!(pool.idle_conns(&peer.addr), 0);
    }

    #[test]
    fn concurrent_checkout_exhausts_then_caps_idle() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let cfg = PoolConfig { max_idle_per_peer: 2, idle_timeout_ms: 30_000 };
        let pool = ConnPool::new(cfg, WireMode::Binary, Some(metrics.clone()));
        // 6 simultaneous holders: the pool must dial past its idle cap
        // (it bounds parked sockets, not in-flight concurrency) ...
        let conns: Vec<PooledConn> = std::thread::scope(|s| {
            let handles: Vec<_> =
                (0..6).map(|_| s.spawn(|| pool.checkout(&peer.addr).unwrap())).collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(counter(&metrics, "pool.dials"), 6, "exhausted pool must dial");
        // ... and keep only max_idle of them at checkin
        for c in conns {
            pool.checkin(&peer.addr, c);
        }
        assert_eq!(pool.idle_conns(&peer.addr), 2);
        assert_eq!(counter(&metrics, "pool.evictions"), 4);
        // the parked pair still serves calls
        pool.call(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap();
        assert_eq!(counter(&metrics, "pool.hits"), 1);
    }

    #[test]
    fn invalidate_drops_idle_and_blocks_stale_checkin() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        let held = pool.checkout(&peer.addr).unwrap();
        pool.call(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap();
        assert_eq!(pool.idle_conns(&peer.addr), 1);
        pool.invalidate(&peer.addr);
        assert_eq!(pool.idle_conns(&peer.addr), 0);
        // a conn checked out before the invalidation must not re-enter
        pool.checkin(&peer.addr, held);
        assert_eq!(pool.idle_conns(&peer.addr), 0);
        assert!(counter(&metrics, "pool.evictions") >= 2);
    }

    /// The ISSUE 5 satellite pin: keepalive probes are invisible to
    /// `pool.dials` (and to the pool's contents), so the
    /// dials-once-per-worker scatter invariant survives health checking.
    #[test]
    fn probe_peer_counts_keepalives_not_dials() {
        let peer = MiniPeer::start(WireMode::Binary);
        let metrics = Registry::new();
        let pool =
            ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()));
        // no parked connection: the probe dials, but only the keepalive
        // counter moves, and nothing is parked or negotiated
        assert!(pool.probe_peer(&peer.addr, Duration::from_millis(500)));
        assert_eq!(counter(&metrics, "pool.keepalive_probes"), 1);
        assert_eq!(counter(&metrics, "pool.dials"), 0, "probes must not count as dials");
        assert_eq!(pool.idle_conns(&peer.addr), 0, "probes must not park connections");
        // with a healthy parked connection the probe answers by peek
        // (no dial at all), but still counts as a probe
        pool.call(&peer.addr, "echo", &Payload::json(Value::Null), None).unwrap();
        assert!(pool.probe_peer(&peer.addr, Duration::from_millis(500)));
        assert_eq!(counter(&metrics, "pool.keepalive_probes"), 2);
        // a dead peer fails the probe without touching pool.dials
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        assert!(!pool.probe_peer(&dead, Duration::from_millis(300)));
        assert_eq!(counter(&metrics, "pool.keepalive_probes"), 3);
        assert_eq!(counter(&metrics, "pool.dials"), 1, "only the real call dialed");
    }

    #[test]
    fn backoff_window_grows_caps_and_jitters_deterministically() {
        for streak in 1..=12u32 {
            let raw = BACKOFF_BASE_MS
                .saturating_mul(1u64 << (streak - 1).min(10))
                .min(BACKOFF_CAP_MS);
            let w = backoff_wait_ms("10.0.0.1:7001", streak);
            assert!(
                w >= raw / 2 && w <= raw,
                "streak {streak}: wait {w}ms outside [{}, {raw}]",
                raw / 2
            );
            assert_eq!(
                w,
                backoff_wait_ms("10.0.0.1:7001", streak),
                "jitter must be deterministic per (addr, streak)"
            );
        }
        // different peers land on different points of the window
        assert!(backoff_wait_ms("a:1", 40) <= BACKOFF_CAP_MS);
    }

    /// The ISSUE 7 satellite pin: a dead peer's redials open a growing
    /// wait window (counted under `pool.backoff_ms`) instead of
    /// hot-looping connect attempts, and the very first dial never waits.
    #[test]
    fn dead_peer_redials_back_off_instead_of_hot_looping() {
        // grab a port, then free it: connects get an instant refusal,
        // so any pool.backoff_ms growth is from the backoff sleep alone
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let metrics = Registry::new();
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, Some(metrics.clone()))
            .with_timeouts(Duration::from_millis(200), Duration::from_millis(200));
        pool.call(&addr, "echo", &Payload::json(Value::Null), None).unwrap_err();
        assert_eq!(counter(&metrics, "pool.backoff_ms"), 0, "first dial must not back off");
        pool.call(&addr, "echo", &Payload::json(Value::Null), None).unwrap_err();
        let after_second = counter(&metrics, "pool.backoff_ms");
        // the counted wait is the window minus time already elapsed since
        // the failure, so allow a few ms of rounding below the jitter floor
        assert!(
            after_second >= BACKOFF_BASE_MS / 2 - 5,
            "second dial should wait out ~the base window, waited {after_second}ms"
        );
        pool.call(&addr, "echo", &Payload::json(Value::Null), None).unwrap_err();
        let after_third = counter(&metrics, "pool.backoff_ms");
        assert!(after_third > after_second, "the window must grow with the streak");
        assert!(after_third <= 3 * BACKOFF_CAP_MS, "windows must stay capped");
    }

    #[test]
    fn dial_failure_propagates_as_io() {
        // grab a port, then free it: nothing listens there
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let pool = ConnPool::new(PoolConfig::default(), WireMode::Binary, None)
            .with_timeouts(Duration::from_millis(300), Duration::from_millis(300));
        let err = pool.call(&addr, "echo", &Payload::json(Value::Null), None).unwrap_err();
        assert!(matches!(err, RpcError::Io(_)), "{err}");
        assert!(matches!(
            dial("not-an-address", Duration::from_millis(100)),
            Err(RpcError::Malformed(_))
        ));
    }
}

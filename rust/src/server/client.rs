//! `AlClient` — the user-facing API of Figure 2:
//!
//! ```text
//! al_client = Client(al_server_url)
//! al_client.push_data(data_list)
//! selected = al_client.query(budget=10)
//! ```
//!
//! On connect the client dials through a [`ConnPool`] holding one
//! persistent connection, negotiated with one `hello` round trip
//! (DESIGN.md §Wire): a v2-capable server answers `{wire: "binary"}` and
//! subsequent frames carry tensors as raw f32 sections; a JSON-forced or
//! pre-v2 server leaves the connection on the v1 JSON wire.
//! `connect_with_wire(addr, WireMode::Json)` skips the probe and forces
//! v1 frames. If the pooled connection goes stale (server restart, idle
//! close), the next call transparently re-dials and re-negotiates.

use std::time::Duration;

use crate::agent::job as agent_job;
use crate::agent::{PsheaConfig, PsheaTrace};
use crate::json::{Map, Value};
use crate::server::pool::{ConnPool, PoolConfig, SubEvent, Subscription};
use crate::server::rpc::RpcError;
use crate::server::wire::{Payload, WireMode};
use crate::store::{Manifest, SampleRef};
use crate::util::mat::Mat;

/// Blocking RPC client for an AL server.
pub struct AlClient {
    pool: ConnPool,
    addr: String,
    mode: WireMode,
}

/// The client keeps exactly one parked connection (it is a sequential,
/// blocking API) and tolerates long pauses between calls before the pool
/// ages it out and transparently re-dials.
fn client_pool_config() -> PoolConfig {
    PoolConfig { max_idle_per_peer: 1, idle_timeout_ms: 300_000 }
}

/// Connect bound for `connect`/`connect_with_wire` (and any transparent
/// re-dial): generous enough for a lossy link's SYN retransmits, but a
/// black-holed peer fails the constructor instead of hanging for the
/// OS default (minutes). Use [`AlClient::connect_timeout`] for a
/// tighter bound.
const CLIENT_DIAL_TIMEOUT: Duration = Duration::from_secs(30);
/// Read deadline for the dial-time `hello` negotiation.
const CLIENT_HELLO_TIMEOUT: Duration = Duration::from_secs(10);

impl AlClient {
    /// Connect to `addr` ("host:port"), preferring the binary wire.
    /// Connect attempts are bounded by [`CLIENT_DIAL_TIMEOUT`].
    pub fn connect(addr: &str) -> Result<AlClient, RpcError> {
        Self::connect_with_wire(addr, WireMode::Binary)
    }

    /// Connect with an explicit wire preference. `Binary` performs the
    /// `hello` negotiation (falling back to JSON when the peer refuses or
    /// predates it); `Json` skips the probe and speaks v1 frames only.
    pub fn connect_with_wire(addr: &str, prefer: WireMode) -> Result<AlClient, RpcError> {
        let pool = ConnPool::new(client_pool_config(), prefer, None)
            .with_timeouts(CLIENT_DIAL_TIMEOUT, CLIENT_HELLO_TIMEOUT);
        Self::establish(pool, addr)
    }

    /// Connect with a timeout (binary-preferring, like `connect`); the
    /// timeout also bounds the `hello` negotiation round trip.
    pub fn connect_timeout(
        addr: std::net::SocketAddr,
        timeout: Duration,
    ) -> Result<AlClient, RpcError> {
        let pool = ConnPool::new(client_pool_config(), WireMode::Binary, None)
            .with_timeouts(timeout, timeout);
        Self::establish(pool, &addr.to_string())
    }

    /// Eagerly dial + negotiate the first connection so an unreachable or
    /// hung peer fails the constructor, and `wire_mode()` reports the
    /// negotiated plane immediately. Against a mux-granting peer this
    /// establishes the shared multiplexed connection every later call
    /// rides on.
    fn establish(pool: ConnPool, addr: &str) -> Result<AlClient, RpcError> {
        let mode = pool.establish(addr)?;
        Ok(AlClient { pool, addr: addr.to_string(), mode })
    }

    /// The wire encoding negotiated for the current pooled connection (a
    /// transparent re-dial may renegotiate it).
    pub fn wire_mode(&self) -> WireMode {
        self.mode
    }

    /// Raw RPC call with tensor sections — the escape hatch the cluster
    /// layer uses for matrix-bearing methods outside the Figure 2 API.
    ///
    /// Retry semantics: a parked connection that dies mid-exchange is
    /// transparently re-dialed and the request **re-sent once** — fine
    /// for the idempotent built-in methods, but a non-idempotent custom
    /// method may execute twice; use [`AlClient::call_wire_once`] for
    /// those.
    pub fn call_wire(&mut self, method: &str, params: Payload) -> Result<Payload, RpcError> {
        self.call_raw(method, params, true)
    }

    /// [`AlClient::call_wire`] without the stale-connection re-send: an
    /// ambiguous mid-exchange failure surfaces as an error instead of
    /// possibly executing the method twice (what the built-in
    /// `agent_start` wrapper uses).
    pub fn call_wire_once(
        &mut self,
        method: &str,
        params: Payload,
    ) -> Result<Payload, RpcError> {
        self.call_raw(method, params, false)
    }

    fn call_raw(
        &mut self,
        method: &str,
        params: Payload,
        retry_stale: bool,
    ) -> Result<Payload, RpcError> {
        let (body, mode) = if retry_stale {
            self.pool.call_negotiated(&self.addr, method, &params, None)?
        } else {
            self.pool.call_once(&self.addr, method, &params, None)?
        };
        // track renegotiations so mode-sensitive encodes (push_data's
        // label form) follow the live connection
        self.mode = mode;
        Ok(body.into_payload())
    }

    /// Raw RPC call returning a plain `Value` (tensor sections, if the
    /// server sent any, are inlined into it).
    pub fn call(&mut self, method: &str, params: Value) -> Result<Value, RpcError> {
        self.call_wire(method, Payload::json(params))?.into_inline_value()
    }

    /// Liveness check.
    pub fn ping(&mut self) -> Result<(), RpcError> {
        let v = self.call("ping", Value::Null)?;
        if v.as_str() == Some("pong") {
            Ok(())
        } else {
            Err(RpcError::Malformed(format!("unexpected ping reply: {v:?}")))
        }
    }

    /// Push a dataset manifest; the server starts processing in the
    /// background. `init_labels` (parallel to `manifest.init`) lets the
    /// server fine-tune the head on the seed set before scoring the pool.
    /// On the binary wire the labels ride as a tensor section; on JSON
    /// they keep the v1 integer-array form.
    ///
    /// Deprecated in favor of [`AlClient::create_session`] +
    /// [`SessionHandle::push`]: the stringly-typed form bypasses the
    /// explicit session lifecycle (it auto-registers under the tenancy
    /// quota and never releases its slot until something closes it).
    pub fn push_data(
        &mut self,
        session: &str,
        manifest: &Manifest,
        init_labels: Option<&[u8]>,
    ) -> Result<(), RpcError> {
        let mut payload = Payload::default();
        let mut p = Map::new();
        p.insert("session", Value::from(session));
        p.insert("manifest", manifest.to_value());
        if let Some(l) = init_labels {
            match self.mode {
                WireMode::Binary => {
                    let m = Mat::from_vec(
                        l.iter().map(|&x| x as f32).collect(),
                        1,
                        l.len(),
                    );
                    p.insert("init_labels", payload.stash_mat(m));
                }
                WireMode::Json => {
                    p.insert(
                        "init_labels",
                        Value::Array(l.iter().map(|&x| Value::from(x as u64)).collect()),
                    );
                }
            }
        }
        payload.value = Value::Object(p);
        self.call_wire("push_data", payload)?;
        Ok(())
    }

    /// Session processing status string ("processing" / "ready" / ...).
    ///
    /// Deprecated in favor of [`SessionHandle::status`] (see
    /// [`AlClient::create_session`]).
    pub fn status(&mut self, session: &str) -> Result<String, RpcError> {
        let mut p = Map::new();
        p.insert("session", Value::from(session));
        let v = self.call("status", Value::Object(p))?;
        Ok(v.get("status").and_then(Value::as_str).unwrap_or("unknown").to_string())
    }

    /// Select `budget` samples (blocking until the scan is ready).
    /// Returns (selected refs, strategy used, select-phase millis).
    ///
    /// Deprecated in favor of [`SessionHandle::query`] (see
    /// [`AlClient::create_session`]).
    pub fn query(
        &mut self,
        session: &str,
        budget: usize,
        strategy: Option<&str>,
    ) -> Result<(Vec<SampleRef>, String, f64), RpcError> {
        let mut p = Map::new();
        p.insert("session", Value::from(session));
        p.insert("budget", Value::from(budget));
        if let Some(s) = strategy {
            p.insert("strategy", Value::from(s));
        }
        let v = self.call("query", Value::Object(p))?;
        let strategy = v
            .get("strategy")
            .and_then(Value::as_str)
            .unwrap_or_default()
            .to_string();
        let select_ms = v.get("select_ms").and_then(Value::as_f64).unwrap_or(0.0);
        let selected = v
            .get("selected")
            .and_then(Value::as_array)
            .ok_or_else(|| RpcError::Malformed("missing selected".into()))?
            .iter()
            .map(|e| {
                let id = e
                    .get("id")
                    .and_then(Value::as_usize)
                    .ok_or_else(|| RpcError::Malformed("selected entry missing id".into()))?;
                let uri = e
                    .get("uri")
                    .and_then(Value::as_str)
                    .ok_or_else(|| RpcError::Malformed("selected entry missing uri".into()))?;
                Ok(SampleRef { id: id as u32, uri: uri.to_string() })
            })
            .collect::<Result<Vec<_>, RpcError>>()?;
        Ok((selected, strategy, select_ms))
    }

    /// Server metrics snapshot (counters/histograms/meters JSON).
    pub fn metrics(&mut self) -> Result<Value, RpcError> {
        self.call("metrics", Value::Null)
    }

    /// Server metrics in the Prometheus text exposition format.
    pub fn metrics_text(&mut self) -> Result<String, RpcError> {
        let v = self.call("metrics_text", Value::Null)?;
        v.as_str()
            .map(str::to_string)
            .ok_or_else(|| RpcError::Malformed("metrics_text reply is not a string".into()))
    }

    /// Recent trace roots + the slow-query log (DESIGN.md
    /// §Observability): `{enabled, slow_query_ms, roots, slow}`. `limit
    /// = 0` returns the server's default window.
    pub fn trace_recent(&mut self, limit: usize) -> Result<Value, RpcError> {
        let mut p = Map::new();
        if limit > 0 {
            p.insert("n", Value::from(limit));
        }
        self.call("trace_recent", Value::Object(p))
    }

    /// Every retained span of one trace, assembled end-to-end (worker
    /// subtrees included). Returns the reply's `spans` decoded.
    pub fn trace_get(
        &mut self,
        trace_id: u64,
    ) -> Result<Vec<crate::trace::SpanRecord>, RpcError> {
        let mut p = Map::new();
        p.insert("trace", Value::from(trace_id));
        let v = self.call("trace_get", Value::Object(p))?;
        let spans = v
            .get("spans")
            .ok_or_else(|| RpcError::Malformed("trace_get reply missing spans".into()))?;
        Ok(crate::trace::spans_from_value(spans))
    }

    /// Data-cache statistics.
    pub fn cache_stats(&mut self) -> Result<Value, RpcError> {
        self.call("cache_stats", Value::Null)
    }

    /// Renew (or establish) `worker_addr`'s membership lease with a
    /// coordinator (DESIGN.md §Cluster). Returns the membership view
    /// generation — 0 when the coordinator has membership disabled and
    /// the beat degraded to a static `register`.
    pub fn heartbeat(&mut self, worker_addr: &str) -> Result<u64, RpcError> {
        let mut p = Map::new();
        p.insert("addr", Value::from(worker_addr));
        let v = self.call("heartbeat", Value::Object(p))?;
        Ok(v.get("generation").and_then(Value::as_usize).unwrap_or(0) as u64)
    }

    /// The coordinator's generation-numbered membership view:
    /// `{enabled, generation, members: [{addr, lease_ms_left?}]}`.
    pub fn members(&mut self) -> Result<Value, RpcError> {
        self.call("members", Value::Null)
    }

    /// Gracefully remove `worker_addr` from the membership view (its
    /// pool rows rebalance across the survivors at the next scatter).
    /// Returns whether the address was a member.
    pub fn deregister(&mut self, worker_addr: &str) -> Result<bool, RpcError> {
        let mut p = Map::new();
        p.insert("addr", Value::from(worker_addr));
        let v = self.call("deregister", Value::Object(p))?;
        Ok(v.get("left").and_then(Value::as_bool).unwrap_or(false))
    }

    /// Names in the server's strategy zoo.
    pub fn strategies(&mut self) -> Result<Vec<String>, RpcError> {
        let v = self.call("strategies", Value::Null)?;
        Ok(v.as_array()
            .map(|a| a.iter().filter_map(|x| x.as_str().map(str::to_string)).collect())
            .unwrap_or_default())
    }

    /// Start a server-side PSHEA job over a pushed session (DESIGN.md
    /// §Agent): the server runs Algorithm 1 in the background, selecting
    /// through its normal query path (across worker shards on a
    /// coordinator). `pool_labels`/`test_labels` are the oracle arrays
    /// parallel to the manifest's pool/test splits; `seed` must match the
    /// in-process experiment's seed for trace parity. Returns the job id.
    pub fn agent_start(
        &mut self,
        session: &str,
        strategies: &[String],
        cfg: &PsheaConfig,
        pool_labels: &[u8],
        test_labels: &[u8],
        seed: u64,
    ) -> Result<String, RpcError> {
        let mut p = Map::new();
        p.insert("session", Value::from(session));
        p.insert(
            "strategies",
            Value::Array(strategies.iter().map(|s| Value::from(s.clone())).collect()),
        );
        p.insert("config", agent_job::config_to_value(cfg));
        p.insert("seed", Value::from(seed));
        // labels stay in the v1 integer-array form on both wires: they
        // are split-sized (bytes, not matrices) and must survive a JSON
        // renegotiation of this exact payload
        let labels = |l: &[u8]| {
            Value::Array(l.iter().map(|&x| Value::from(x as u64)).collect())
        };
        p.insert("pool_labels", labels(pool_labels));
        p.insert("test_labels", labels(test_labels));
        // agent_start spawns a background job server-side: never let the
        // pool silently re-send it after an ambiguous mid-exchange
        // failure, or two jobs could spend the labeling budget
        let v = self
            .call_raw("agent_start", Payload::json(Value::Object(p)), false)?
            .into_inline_value()?;
        v.get("job")
            .and_then(Value::as_str)
            .map(str::to_string)
            .ok_or_else(|| RpcError::Malformed("agent_start reply missing job id".into()))
    }

    /// Subscribe to a job's push-event stream (DESIGN.md §Events): the
    /// server pushes every job event — spends, round results,
    /// eliminations, resume/cancel/done — as unsolicited frames on the
    /// multiplexed connection, in the exact order and byte shape its
    /// durable WAL records them. `from_seq` is the last sequence number
    /// already consumed (0 for a fresh subscription); the server replays
    /// everything after it from the job's retained buffer, so a
    /// reconnecting follower resumes without gaps or duplicates.
    ///
    /// Requires the multiplexed v2 wire — a JSON-forced or pre-mux peer
    /// returns a typed refusal. Supersedes polling
    /// [`AlClient::agent_status`] in a sleep loop.
    pub fn subscribe_job(
        &mut self,
        job: &str,
        from_seq: u64,
    ) -> Result<JobEventStream, RpcError> {
        let mut p = Map::new();
        p.insert("job", Value::from(job));
        p.insert("from_seq", Value::from(from_seq));
        let (body, sub) = self.pool.subscribe(
            &self.addr,
            "job_subscribe",
            &Payload::json(Value::Object(p)),
            Some(CLIENT_HELLO_TIMEOUT),
        )?;
        let ack = body.into_payload().into_inline_value()?;
        let status = ack
            .get("status")
            .and_then(Value::as_str)
            .unwrap_or("unknown")
            .to_string();
        let next_seq = ack.get("next_seq").and_then(Value::as_usize).unwrap_or(0) as u64;
        Ok(JobEventStream {
            sub,
            status,
            next_seq,
            cursor: from_seq,
            done: false,
            end_reason: None,
        })
    }

    /// Mid-run job state: status string, round log, live/eliminated arms,
    /// budget spent (the raw `agent_status` reply).
    ///
    /// Deprecated as a progress poll: prefer [`AlClient::subscribe_job`],
    /// which pushes every event instead of sampling state on a timer
    /// (this call remains the state snapshot for catch-up after a
    /// `Lagged` disconnect).
    pub fn agent_status(&mut self, job: &str) -> Result<Value, RpcError> {
        let mut p = Map::new();
        p.insert("job", Value::from(job));
        self.call("agent_status", Value::Object(p))
    }

    /// Block until the job completes and return its full trace. A
    /// cancelled or failed job surfaces as a `Remote` error.
    pub fn agent_result(&mut self, job: &str, wait: Duration) -> Result<PsheaTrace, RpcError> {
        let mut p = Map::new();
        p.insert("job", Value::from(job));
        p.insert("wait_ms", Value::from(wait.as_millis().min(u64::MAX as u128) as u64));
        let v = self.call("agent_result", Value::Object(p))?;
        agent_job::trace_from_value(&v).map_err(RpcError::Malformed)
    }

    /// Request cancellation; labeling spend stops at the next round
    /// boundary. Returns whether the job was still running.
    pub fn agent_cancel(&mut self, job: &str) -> Result<bool, RpcError> {
        let mut p = Map::new();
        p.insert("job", Value::from(job));
        let v = self.call("agent_cancel", Value::Object(p))?;
        Ok(v.get("cancelled").and_then(Value::as_bool).unwrap_or(false))
    }

    /// Explicitly register a session under the server's tenancy quota
    /// and mint its opaque `tok-*` handle (DESIGN.md §Tenancy). The
    /// returned [`SessionHandle`] scopes every follow-up call to the
    /// session and releases the quota slot on [`SessionHandle::close`]
    /// (or best-effort on drop). Re-creating an existing name is
    /// idempotent and returns the already-minted token.
    pub fn create_session(
        &mut self,
        name: &str,
        opts: SessionOpts,
    ) -> Result<SessionHandle<'_>, RpcError> {
        let mut p = Map::new();
        p.insert("session", Value::from(name));
        p.insert("weight", Value::from(opts.weight));
        p.insert("max_workers", Value::from(opts.max_workers));
        let v = self.call("session_create", Value::Object(p))?;
        let token = v
            .get("token")
            .and_then(Value::as_str)
            .map(str::to_string)
            .ok_or_else(|| {
                RpcError::Malformed("session_create reply missing token".into())
            })?;
        Ok(SessionHandle { client: self, name: name.to_string(), token, closed: false })
    }

    /// Close a session by name or `tok-*` handle: the quota slot is
    /// released and its resident shard memory freed on the workers.
    /// Idempotent — closing an already-closed session returns `false`.
    pub fn close_session(&mut self, name_or_token: &str) -> Result<bool, RpcError> {
        let mut p = Map::new();
        p.insert("session", Value::from(name_or_token));
        let v = self.call("session_close", Value::Object(p))?;
        Ok(v.get("closed").and_then(Value::as_bool).unwrap_or(false))
    }

    /// The service's tenancy snapshot: session registry, admission-gate
    /// counters, and per-session data footprints (`alaas sessions`).
    pub fn service_stats(&mut self) -> Result<Value, RpcError> {
        self.call("service_stats", Value::Null)
    }
}

/// Options for [`AlClient::create_session`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SessionOpts {
    /// Fair-share weight in the coordinator's admission gate (deficit
    /// round-robin quantum). A weight-3 session drains scatters ~3× as
    /// fast as a weight-1 session under saturation.
    pub weight: u64,
    /// Cap on the workers this session's pool is sharded across
    /// (0 = uncapped; combined with `coordinator.tenancy.
    /// max_workers_per_session` by `min`).
    pub max_workers: usize,
}

impl Default for SessionOpts {
    fn default() -> SessionOpts {
        SessionOpts { weight: 1, max_workers: 0 }
    }
}

/// An explicitly-created session: the typed replacement for the
/// stringly `session: &str` API. Calls route through the session's
/// opaque `tok-*` token, so a stale or mistyped name cannot silently
/// address another tenant's data. Dropping the handle closes the
/// session best-effort; call [`SessionHandle::close`] to observe the
/// outcome, or [`SessionHandle::detach`] to keep it alive.
pub struct SessionHandle<'c> {
    client: &'c mut AlClient,
    name: String,
    token: String,
    closed: bool,
}

impl SessionHandle<'_> {
    /// The session name this handle was created with.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The minted `tok-*` token (opaque; valid until close or restart
    /// of a non-durable server).
    pub fn token(&self) -> &str {
        &self.token
    }

    /// [`AlClient::push_data`] scoped to this session.
    pub fn push(
        &mut self,
        manifest: &Manifest,
        init_labels: Option<&[u8]>,
    ) -> Result<(), RpcError> {
        let tok = self.token.clone();
        self.client.push_data(&tok, manifest, init_labels)
    }

    /// [`AlClient::status`] scoped to this session.
    pub fn status(&mut self) -> Result<String, RpcError> {
        let tok = self.token.clone();
        self.client.status(&tok)
    }

    /// [`AlClient::query`] scoped to this session.
    pub fn query(
        &mut self,
        budget: usize,
        strategy: Option<&str>,
    ) -> Result<(Vec<SampleRef>, String, f64), RpcError> {
        let tok = self.token.clone();
        self.client.query(&tok, budget, strategy)
    }

    /// [`AlClient::agent_start`] scoped to this session.
    pub fn agent_start(
        &mut self,
        strategies: &[String],
        cfg: &PsheaConfig,
        pool_labels: &[u8],
        test_labels: &[u8],
        seed: u64,
    ) -> Result<String, RpcError> {
        let tok = self.token.clone();
        self.client.agent_start(&tok, strategies, cfg, pool_labels, test_labels, seed)
    }

    /// [`AlClient::subscribe_job`] through this handle's client (job ids
    /// are service-global; the handle is a convenience router).
    pub fn subscribe_job(
        &mut self,
        job: &str,
        from_seq: u64,
    ) -> Result<JobEventStream, RpcError> {
        self.client.subscribe_job(job, from_seq)
    }

    /// Close the session, releasing its quota slot and freeing resident
    /// shard memory on the workers. Returns whether the service still
    /// knew the session.
    pub fn close(mut self) -> Result<bool, RpcError> {
        self.closed = true;
        let tok = self.token.clone();
        self.client.close_session(&tok)
    }

    /// Consume the handle WITHOUT closing the session; returns
    /// `(name, token)` so the session can be re-addressed later (e.g.
    /// from another process via the token string).
    pub fn detach(mut self) -> (String, String) {
        self.closed = true;
        (self.name.clone(), self.token.clone())
    }
}

impl Drop for SessionHandle<'_> {
    fn drop(&mut self) {
        if !self.closed {
            let tok = std::mem::take(&mut self.token);
            let _ = self.client.close_session(&tok);
        }
    }
}

/// One pushed job event: `seq` is the job's monotonically increasing
/// sequence number (1-based, no gaps within a stream), `value` the event
/// record verbatim — on a durable coordinator, byte-identical to the WAL
/// record the same state change appended.
#[derive(Debug, Clone)]
pub struct JobEvent {
    pub seq: u64,
    pub value: Value,
}

/// A live job event stream from [`AlClient::subscribe_job`]: a blocking
/// iterator yielding every pushed event until the job reaches a terminal
/// state (the server ends the stream) or the connection dies (one `Err`
/// item, then `None`). The stream owns its demux slot independently of
/// the client, so the client can keep issuing RPCs — even on the same
/// multiplexed connection — while a follower drains events.
pub struct JobEventStream {
    sub: Subscription,
    status: String,
    next_seq: u64,
    cursor: u64,
    done: bool,
    end_reason: Option<String>,
}

/// How long one iterator step parks before re-checking for a push; only
/// an internal wake-up cadence — `next` blocks until a real delivery.
const SUB_IDLE_POLL: Duration = Duration::from_millis(250);

impl JobEventStream {
    /// Job status string at subscribe time ("running", "done", ...).
    pub fn status(&self) -> &str {
        &self.status
    }

    /// The server's next sequence number at subscribe time — everything
    /// in `(from_seq, next_seq)` is replayed before live events.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Highest sequence number yielded so far (equals the subscribe-time
    /// `from_seq` until the first event). Pass this back to
    /// [`AlClient::subscribe_job`] to resume after a disconnect without
    /// gaps or duplicates.
    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    /// Why the stream ended, once it has cleanly ("all events
    /// delivered" after a terminal job). A lag disconnect — this
    /// subscriber fell behind the retained buffer and must catch up via
    /// `agent_status` + resubscribe — surfaces as an `Err` item instead.
    pub fn end_reason(&self) -> Option<&str> {
        self.end_reason.as_deref()
    }
}

impl Iterator for JobEventStream {
    type Item = Result<JobEvent, RpcError>;

    fn next(&mut self) -> Option<Result<JobEvent, RpcError>> {
        if self.done {
            return None;
        }
        loop {
            match self.sub.next(SUB_IDLE_POLL) {
                Ok(SubEvent::Event { seq, value }) => {
                    self.cursor = seq;
                    return Some(Ok(JobEvent { seq, value }));
                }
                Ok(SubEvent::End(reason)) => {
                    self.done = true;
                    self.end_reason = Some(reason);
                    return None;
                }
                Ok(SubEvent::Idle) => continue,
                Err(e) => {
                    self.done = true;
                    return Some(Err(e));
                }
            }
        }
    }
}

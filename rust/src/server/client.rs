//! `AlClient` — the user-facing API of Figure 2:
//!
//! ```text
//! al_client = Client(al_server_url)
//! al_client.push_data(data_list)
//! selected = al_client.query(budget=10)
//! ```

use std::net::TcpStream;
use std::time::Duration;

use crate::json::{Map, Value};
use crate::server::rpc::{self, RpcError};
use crate::store::{Manifest, SampleRef};

/// Blocking RPC client for an AL server.
pub struct AlClient {
    stream: TcpStream,
    next_id: u64,
}

impl AlClient {
    /// Connect to `addr` ("host:port").
    pub fn connect(addr: &str) -> Result<AlClient, RpcError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(AlClient { stream, next_id: 1 })
    }

    /// Connect with a timeout.
    pub fn connect_timeout(
        addr: std::net::SocketAddr,
        timeout: Duration,
    ) -> Result<AlClient, RpcError> {
        let stream = TcpStream::connect_timeout(&addr, timeout)?;
        stream.set_nodelay(true).ok();
        Ok(AlClient { stream, next_id: 1 })
    }

    /// Raw RPC call — the escape hatch the cluster layer uses for methods
    /// outside the Figure 2 client API (`register`, `scan_shard`, ...).
    pub fn call(&mut self, method: &str, params: Value) -> Result<Value, RpcError> {
        let id = self.next_id;
        self.next_id += 1;
        rpc::send_request(&mut self.stream, id, method, params)?;
        rpc::recv_response(&mut self.stream, id)
    }

    /// Liveness check.
    pub fn ping(&mut self) -> Result<(), RpcError> {
        let v = self.call("ping", Value::Null)?;
        if v.as_str() == Some("pong") {
            Ok(())
        } else {
            Err(RpcError::Malformed(format!("unexpected ping reply: {v:?}")))
        }
    }

    /// Push a dataset manifest; the server starts processing in the
    /// background. `init_labels` (parallel to `manifest.init`) lets the
    /// server fine-tune the head on the seed set before scoring the pool.
    pub fn push_data(
        &mut self,
        session: &str,
        manifest: &Manifest,
        init_labels: Option<&[u8]>,
    ) -> Result<(), RpcError> {
        let mut p = Map::new();
        p.insert("session", Value::from(session));
        p.insert("manifest", manifest.to_value());
        if let Some(l) = init_labels {
            p.insert(
                "init_labels",
                Value::Array(l.iter().map(|&x| Value::from(x as u64)).collect()),
            );
        }
        self.call("push_data", Value::Object(p))?;
        Ok(())
    }

    /// Session processing status string ("processing" / "ready" / ...).
    pub fn status(&mut self, session: &str) -> Result<String, RpcError> {
        let mut p = Map::new();
        p.insert("session", Value::from(session));
        let v = self.call("status", Value::Object(p))?;
        Ok(v.get("status").and_then(Value::as_str).unwrap_or("unknown").to_string())
    }

    /// Select `budget` samples (blocking until the scan is ready).
    /// Returns (selected refs, strategy used, select-phase millis).
    pub fn query(
        &mut self,
        session: &str,
        budget: usize,
        strategy: Option<&str>,
    ) -> Result<(Vec<SampleRef>, String, f64), RpcError> {
        let mut p = Map::new();
        p.insert("session", Value::from(session));
        p.insert("budget", Value::from(budget));
        if let Some(s) = strategy {
            p.insert("strategy", Value::from(s));
        }
        let v = self.call("query", Value::Object(p))?;
        let strategy = v
            .get("strategy")
            .and_then(Value::as_str)
            .unwrap_or_default()
            .to_string();
        let select_ms = v.get("select_ms").and_then(Value::as_f64).unwrap_or(0.0);
        let selected = v
            .get("selected")
            .and_then(Value::as_array)
            .ok_or_else(|| RpcError::Malformed("missing selected".into()))?
            .iter()
            .map(|e| {
                let id = e
                    .get("id")
                    .and_then(Value::as_usize)
                    .ok_or_else(|| RpcError::Malformed("selected entry missing id".into()))?;
                let uri = e
                    .get("uri")
                    .and_then(Value::as_str)
                    .ok_or_else(|| RpcError::Malformed("selected entry missing uri".into()))?;
                Ok(SampleRef { id: id as u32, uri: uri.to_string() })
            })
            .collect::<Result<Vec<_>, RpcError>>()?;
        Ok((selected, strategy, select_ms))
    }

    /// Server metrics snapshot (counters/histograms/meters JSON).
    pub fn metrics(&mut self) -> Result<Value, RpcError> {
        self.call("metrics", Value::Null)
    }

    /// Data-cache statistics.
    pub fn cache_stats(&mut self) -> Result<Value, RpcError> {
        self.call("cache_stats", Value::Null)
    }

    /// Names in the server's strategy zoo.
    pub fn strategies(&mut self) -> Result<Vec<String>, RpcError> {
        let v = self.call("strategies", Value::Null)?;
        Ok(v.as_array()
            .map(|a| a.iter().filter_map(|x| x.as_str().map(str::to_string)).collect())
            .unwrap_or_default())
    }
}

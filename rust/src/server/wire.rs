//! Binary tensor data plane: the v2 wire format (DESIGN.md §Wire).
//!
//! A v1 frame is a length-prefixed JSON document. A v2 frame carries the
//! same JSON *control header* plus zero or more raw little-endian f32
//! tensor sections, so matrix-bearing RPCs (`select_shard` candidates,
//! `init_emb`, pushed labels) never pay float formatting/parsing or the
//! ~5-15x JSON size blowup. Layout of a v2 payload (inside the outer
//! 4-byte-LE length frame, which still caps everything at `MAX_FRAME`):
//!
//! ```text
//! [0]      magic 0xBF       (invalid as a UTF-8 first byte, so a v1 peer
//!                            fails fast with "non-utf8 frame")
//! [1]      version (2)
//! [2..4]   n_tensors: u16 LE
//! [4..8]   header_len: u32 LE
//! [8..]    header: UTF-8 JSON (the usual request/response envelope)
//! then per tensor:
//!   rows: u32 LE, cols: u32 LE, rows*cols little-endian f32 values
//! ```
//!
//! Inside the header, a tensor section is referenced by the placeholder
//! object `{"$bin": <section index>}`. Encoding the same payload in JSON
//! mode replaces every placeholder with the inline `{rows, cols, data}`
//! object form, so one handler code path serves both modes and selection
//! results are identical on either wire. Tensor round-trips are bit-exact
//! in binary mode (NaN payloads and infinities survive); JSON mode keeps
//! the v1 behavior (non-finite values serialize as `null` and decode as
//! NaN).

use crate::json::{self, Map, Value};
use crate::util::mat::Mat;

use super::rpc::{RpcError, MAX_FRAME};

/// First byte of a v2 payload. 0xBF is a UTF-8 continuation byte, so it
/// can never begin a v1 JSON frame.
pub const BIN_MAGIC: u8 = 0xBF;

/// Wire protocol version carried in byte 1 of a v2 payload.
pub const WIRE_VERSION: u8 = 2;

/// Error message a JSON-forced server returns for a v2 request; clients
/// match on it to fall back to JSON for that peer.
pub const ERR_BINARY_DISABLED: &str = "binary wire disabled";

/// Which encoding a sender uses (receivers always accept both).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireMode {
    /// v1 frames only: everything inline JSON.
    Json,
    /// v2 frames: JSON control header + raw f32 tensor sections.
    Binary,
}

impl WireMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            WireMode::Json => "json",
            WireMode::Binary => "binary",
        }
    }

    pub fn parse(s: &str) -> Option<WireMode> {
        match s {
            "json" => Some(WireMode::Json),
            "binary" => Some(WireMode::Binary),
            _ => None,
        }
    }
}

/// A decoded (or to-be-encoded) message body: the JSON value plus the
/// tensor sections its `{"$bin": i}` placeholders refer to. In JSON mode
/// the tensors are inlined at encode time and the list is empty after
/// decode.
#[derive(Debug, Clone, PartialEq)]
pub struct Payload {
    pub value: Value,
    pub tensors: Vec<Mat>,
}

impl Default for Payload {
    fn default() -> Self {
        Payload { value: Value::Null, tensors: Vec::new() }
    }
}

impl Payload {
    /// Plain JSON payload with no tensor sections.
    pub fn json(value: Value) -> Payload {
        Payload { value, tensors: Vec::new() }
    }

    /// Append a tensor section and return the placeholder to embed in
    /// `value` wherever the matrix logically lives.
    pub fn stash_mat(&mut self, m: Mat) -> Value {
        self.tensors.push(m);
        placeholder(self.tensors.len() - 1)
    }

    /// Resolve an optional matrix-valued field of `value` (placeholder or
    /// inline `{rows, cols, data}`); `Ok(None)` when absent/null.
    pub fn mat(&self, key: &str) -> Result<Option<Mat>, String> {
        opt_mat(&self.value, &self.tensors, key)
    }

    /// The plain-`Value` view: inlines any tensor sections into the value
    /// (no-op without sections). The v1-compatible shape callers without
    /// bulk data consume.
    pub fn into_inline_value(self) -> Result<Value, RpcError> {
        if self.tensors.is_empty() {
            Ok(self.value)
        } else {
            inline_value(&self.value, &self.tensors)
        }
    }
}

/// Decoded *inbound* message: the envelope/params value plus the tensor
/// sections still sitting in the received frame buffer (zero-copy decode,
/// DESIGN.md §Wire). [`Payload`] is its outbound mirror: handlers receive
/// a `Body`, materialize only the matrices they actually consume (each at
/// most once, straight into its destination), and reply with a `Payload`.
#[derive(Debug, Default)]
pub struct Body {
    pub value: Value,
    pub tensors: TensorBuf,
}

impl Body {
    /// Plain JSON body with no tensor sections.
    pub fn json(value: Value) -> Body {
        Body { value, tensors: TensorBuf::empty() }
    }

    /// Resolve an optional matrix-valued field of `value` (placeholder or
    /// inline `{rows, cols, data}`) into an owned `Mat` — one copy out of
    /// the frame buffer. `Ok(None)` when absent/null.
    pub fn mat(&self, key: &str) -> Result<Option<Mat>, String> {
        Ok(self.mat_ref(key)?.map(MatRef::into_mat))
    }

    /// Borrowed form of [`Body::mat`]: a `MatView` over the frame buffer
    /// for v2 sections, an owned `Mat` for the inline JSON form.
    pub fn mat_ref(&self, key: &str) -> Result<Option<MatRef<'_>>, String> {
        match self.value.get(key) {
            None | Some(Value::Null) => Ok(None),
            Some(v) => self.resolve_ref(v).map(Some),
        }
    }

    fn resolve_ref(&self, v: &Value) -> Result<MatRef<'_>, String> {
        if let Some(i) = placeholder_index(v) {
            self.tensors.view(i).map(MatRef::View).ok_or_else(|| {
                format!("tensor ref ${i} out of range ({} sections)", self.tensors.len())
            })
        } else {
            mat_from_value(v).map(MatRef::Owned)
        }
    }

    /// Matrix form of a value that may be something else entirely (label
    /// arrays keep their v1 integer form): `Ok(None)` when `v` is neither
    /// a placeholder nor an inline matrix object.
    pub fn maybe_mat(&self, v: &Value) -> Result<Option<Mat>, String> {
        if placeholder_index(v).is_some() || is_inline_mat(v) {
            self.resolve_ref(v).map(|m| Some(m.into_mat()))
        } else {
            Ok(None)
        }
    }

    /// Materialize every section — the owned, v1-compatible view for
    /// callers that keep the tensors around.
    pub fn into_payload(self) -> Payload {
        Payload { value: self.value, tensors: self.tensors.materialize() }
    }

    /// Owned copy with every section materialized (echo/test helper).
    pub fn to_payload(&self) -> Payload {
        Payload { value: self.value.clone(), tensors: self.tensors.materialize() }
    }

    /// Plain-`Value` view: inlines any tensor sections into the value
    /// (no-op without sections).
    pub fn into_inline_value(self) -> Result<Value, RpcError> {
        if self.tensors.is_empty() {
            Ok(self.value)
        } else {
            inline_value(&self.value, &self.tensors.materialize())
        }
    }
}

/// Tensor sections of a decoded v2 frame, kept as raw bytes of the
/// received buffer. Views decode f32s on access; nothing is materialized
/// until a consumer asks (zero-copy decode).
#[derive(Debug, Default)]
pub struct TensorBuf {
    buf: Vec<u8>,
    sections: Vec<Section>,
}

/// One validated tensor section: shape + byte offset into the frame.
#[derive(Debug, Clone, Copy)]
struct Section {
    rows: usize,
    cols: usize,
    off: usize,
}

impl TensorBuf {
    pub fn empty() -> TensorBuf {
        TensorBuf::default()
    }

    pub fn len(&self) -> usize {
        self.sections.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sections.is_empty()
    }

    /// Borrowed view of section `i`.
    pub fn view(&self, i: usize) -> Option<MatView<'_>> {
        self.sections.get(i).map(|s| MatView {
            data: &self.buf[s.off..s.off + s.rows * s.cols * 4],
            rows: s.rows,
            cols: s.cols,
        })
    }

    /// Owned `Mat` per section (the v1-compatible materialization).
    pub fn materialize(&self) -> Vec<Mat> {
        (0..self.len()).map(|i| self.view(i).expect("indexed section").to_mat()).collect()
    }
}

/// Borrowed `[rows, cols]` f32 matrix over a frame buffer's raw
/// little-endian bytes. Alignment-free by construction: values decode on
/// access with `from_le_bytes`, so the section can start at any offset.
#[derive(Debug, Clone, Copy)]
pub struct MatView<'a> {
    data: &'a [u8],
    rows: usize,
    cols: usize,
}

impl MatView<'_> {
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn get(&self, i: usize, j: usize) -> f32 {
        let o = (i * self.cols + j) * 4;
        f32::from_le_bytes([self.data[o], self.data[o + 1], self.data[o + 2], self.data[o + 3]])
    }

    /// Copy row `i` into a fresh vec — the scatter/merge path's
    /// per-candidate copy, straight from the frame buffer.
    pub fn row_vec(&self, i: usize) -> Vec<f32> {
        let base = i * self.cols * 4;
        let mut out = Vec::with_capacity(self.cols);
        for ch in self.data[base..base + self.cols * 4].chunks_exact(4) {
            out.push(f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
        }
        out
    }

    /// Materialize the whole section as an owned `Mat` (one pass).
    pub fn to_mat(&self) -> Mat {
        let mut vals = Vec::with_capacity(self.rows * self.cols);
        for ch in self.data.chunks_exact(4) {
            vals.push(f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
        }
        Mat::from_vec(vals, self.rows, self.cols)
    }
}

/// Owned-or-borrowed matrix field resolved from a decoded frame.
#[derive(Debug)]
pub enum MatRef<'a> {
    View(MatView<'a>),
    Owned(Mat),
}

impl MatRef<'_> {
    pub fn rows(&self) -> usize {
        match self {
            MatRef::View(v) => v.rows(),
            MatRef::Owned(m) => m.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            MatRef::View(v) => v.cols(),
            MatRef::Owned(m) => m.cols(),
        }
    }

    pub fn row_vec(&self, i: usize) -> Vec<f32> {
        match self {
            MatRef::View(v) => v.row_vec(i),
            MatRef::Owned(m) => m.row(i).to_vec(),
        }
    }

    pub fn into_mat(self) -> Mat {
        match self {
            MatRef::View(v) => v.to_mat(),
            MatRef::Owned(m) => m,
        }
    }
}

/// `{"$bin": idx}`.
pub fn placeholder(idx: usize) -> Value {
    let mut m = Map::new();
    m.insert("$bin", Value::from(idx));
    Value::Object(m)
}

/// Section index when `v` is a tensor placeholder.
pub fn placeholder_index(v: &Value) -> Option<usize> {
    let m = v.as_object()?;
    if m.len() == 1 {
        m.get("$bin")?.as_usize()
    } else {
        None
    }
}

/// True when `v` looks like the inline `{rows, cols, data}` matrix form.
fn is_inline_mat(v: &Value) -> bool {
    v.as_object().is_some_and(|m| {
        m.contains_key("rows") && m.contains_key("cols") && m.contains_key("data")
    })
}

/// Inline JSON form of a matrix: `{rows, cols, data: [f64...]}` row-major
/// (non-finite entries become `null` when serialized to text).
pub fn mat_to_value(m: &Mat) -> Value {
    let mut o = Map::new();
    o.insert("rows", Value::from(m.rows()));
    o.insert("cols", Value::from(m.cols()));
    o.insert("data", f32s_to_value(m.as_slice()));
    Value::Object(o)
}

pub fn mat_from_value(v: &Value) -> Result<Mat, String> {
    let rows = v.get("rows").and_then(Value::as_usize).ok_or("mat missing rows")?;
    let cols = v.get("cols").and_then(Value::as_usize).ok_or("mat missing cols")?;
    let data = f32s_from_value(v.get("data").ok_or("mat missing data")?)?;
    if data.len() != rows * cols {
        return Err(format!("mat data len {} != {rows}x{cols}", data.len()));
    }
    Ok(Mat::from_vec(data, rows, cols))
}

pub fn f32s_to_value(xs: &[f32]) -> Value {
    Value::Array(xs.iter().map(|&x| Value::Number(x as f64)).collect())
}

/// Non-number entries (the `null` a non-finite float serializes to)
/// decode back to NaN, matching the v1 convention.
pub fn f32s_from_value(v: &Value) -> Result<Vec<f32>, String> {
    let arr = v.as_array().ok_or("expected number array")?;
    Ok(arr
        .iter()
        .map(|x| match x {
            Value::Number(n) => *n as f32,
            _ => f32::NAN,
        })
        .collect())
}

/// Resolve a matrix value in either wire form.
pub fn resolve_mat(v: &Value, tensors: &[Mat]) -> Result<Mat, String> {
    if let Some(i) = placeholder_index(v) {
        return tensors
            .get(i)
            .cloned()
            .ok_or_else(|| format!("tensor ref ${i} out of range ({} sections)", tensors.len()));
    }
    mat_from_value(v)
}

/// Optional matrix-valued field: placeholder, inline object, or absent.
pub fn opt_mat(value: &Value, tensors: &[Mat], key: &str) -> Result<Option<Mat>, String> {
    match value.get(key) {
        None | Some(Value::Null) => Ok(None),
        Some(v) => resolve_mat(v, tensors).map(Some),
    }
}

/// Replace every `{"$bin": i}` placeholder in `v` with the inline form of
/// `tensors[i]` (the JSON-mode encoding of a tensor-bearing payload).
pub fn inline_value(v: &Value, tensors: &[Mat]) -> Result<Value, RpcError> {
    if let Some(i) = placeholder_index(v) {
        let m = tensors
            .get(i)
            .ok_or_else(|| RpcError::Malformed(format!("tensor ref ${i} out of range")))?;
        return Ok(mat_to_value(m));
    }
    match v {
        Value::Array(a) => {
            let mut out = Vec::with_capacity(a.len());
            for e in a {
                out.push(inline_value(e, tensors)?);
            }
            Ok(Value::Array(out))
        }
        Value::Object(m) => {
            let mut out = Map::new();
            for (k, e) in m.iter() {
                out.insert(k.to_string(), inline_value(e, tensors)?);
            }
            Ok(Value::Object(out))
        }
        other => Ok(other.clone()),
    }
}

/// Byte length of a tensor section's data, with overflow/size/dimension
/// validation shared by encode and decode (so an oversized section is
/// rejected on both sides, before any allocation on the read side).
fn tensor_byte_len(rows: usize, cols: usize) -> Result<usize, RpcError> {
    if rows > u32::MAX as usize || cols > u32::MAX as usize {
        return Err(RpcError::Malformed(format!("tensor dims {rows}x{cols} exceed u32")));
    }
    let bytes = rows
        .checked_mul(cols)
        .and_then(|e| e.checked_mul(4))
        .ok_or(RpcError::FrameTooLarge(usize::MAX))?;
    if bytes > MAX_FRAME {
        return Err(RpcError::FrameTooLarge(bytes));
    }
    Ok(bytes)
}

/// Assemble a v2 payload from pre-serialized header text + sections.
fn encode_binary(header: Vec<u8>, tensors: &[Mat]) -> Result<Vec<u8>, RpcError> {
    if tensors.len() > u16::MAX as usize {
        return Err(RpcError::Malformed(format!(
            "{} tensor sections exceed the u16 frame field",
            tensors.len()
        )));
    }
    let mut total = 8usize
        .checked_add(header.len())
        .ok_or(RpcError::FrameTooLarge(usize::MAX))?;
    for t in tensors {
        let nbytes = tensor_byte_len(t.rows(), t.cols())?;
        total = total
            .checked_add(8 + nbytes)
            .ok_or(RpcError::FrameTooLarge(usize::MAX))?;
    }
    if total > MAX_FRAME {
        return Err(RpcError::FrameTooLarge(total));
    }
    let mut out = Vec::with_capacity(total);
    out.push(BIN_MAGIC);
    out.push(WIRE_VERSION);
    out.extend_from_slice(&(tensors.len() as u16).to_le_bytes());
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(&header);
    // stage f32s in fixed-size stack chunks so the output grows by bulk
    // appends instead of 640k four-byte pushes for a 10k x 64 section
    // (each paying a length/capacity check)
    let mut stage = [0u8; 4096];
    for t in tensors {
        out.extend_from_slice(&(t.rows() as u32).to_le_bytes());
        out.extend_from_slice(&(t.cols() as u32).to_le_bytes());
        for chunk in t.as_slice().chunks(stage.len() / 4) {
            let mut n = 0;
            for &x in chunk {
                stage[n..n + 4].copy_from_slice(&x.to_le_bytes());
                n += 4;
            }
            out.extend_from_slice(&stage[..n]);
        }
    }
    Ok(out)
}

/// Encode an envelope + tensor sections into frame-payload bytes for
/// `mode`. JSON mode inlines the tensors into the envelope text.
pub fn encode_payload(
    envelope: &Value,
    tensors: &[Mat],
    mode: WireMode,
) -> Result<Vec<u8>, RpcError> {
    match mode {
        WireMode::Json => {
            let text = if tensors.is_empty() {
                json::to_string(envelope)
            } else {
                json::to_string(&inline_value(envelope, tensors)?)
            };
            Ok(text.into_bytes())
        }
        WireMode::Binary => encode_binary(json::to_string(envelope).into_bytes(), tensors),
    }
}

/// Encode a full request/response message without cloning the payload
/// value: the `{"id", "method"?, "params"/"result"}` envelope is spliced
/// as text around the separately-serialized payload (a `push_data`
/// manifest is tens of MB of JSON — building an envelope `Value` around
/// it would deep-copy the tree on the hot path). `method: Some` produces
/// a request with `params`; `None` a response with `result`.
pub fn encode_message(
    id: u64,
    method: Option<&str>,
    payload: &Payload,
    mode: WireMode,
) -> Result<Vec<u8>, RpcError> {
    encode_message_ext(id, method, payload, mode, None)
}

/// [`encode_message`] with an optional extra envelope field, passed as a
/// pre-serialized `"key":value` fragment spliced next to `id`. This is
/// how the trace context (`"trace":{...}` on requests) and the span
/// piggyback (`"trace_spans":[...]` on responses) ride the envelope:
/// decoders read only the keys they know, so old peers skip the field —
/// the same forward-compatibility contract `hello` negotiation relies
/// on.
pub fn encode_message_ext(
    id: u64,
    method: Option<&str>,
    payload: &Payload,
    mode: WireMode,
    extra: Option<&str>,
) -> Result<Vec<u8>, RpcError> {
    let value_text = match mode {
        WireMode::Json if !payload.tensors.is_empty() => {
            json::to_string(&inline_value(&payload.value, &payload.tensors)?)
        }
        _ => json::to_string(&payload.value),
    };
    let extra = match extra {
        Some(frag) => format!(",{frag}"),
        None => String::new(),
    };
    let header = match method {
        Some(m) => format!(
            "{{\"id\":{id}{extra},\"method\":{},\"params\":{value_text}}}",
            json::to_string(&Value::from(m))
        ),
        None => format!("{{\"id\":{id}{extra},\"result\":{value_text}}}"),
    };
    match mode {
        WireMode::Json => Ok(header.into_bytes()),
        WireMode::Binary => encode_binary(header.into_bytes(), &payload.tensors),
    }
}

/// Validate the v2 preamble (magic byte already checked by the caller)
/// and parse the control header. Returns the header value, the section
/// count, and the offset where tensor sections begin — shared by the
/// full decode and the header-only refusal path so the two cannot
/// diverge.
fn decode_v2_preamble(bytes: &[u8]) -> Result<(Value, usize, usize), RpcError> {
    if bytes.len() < 8 {
        return Err(RpcError::Malformed("truncated v2 frame header".into()));
    }
    if bytes[1] != WIRE_VERSION {
        return Err(RpcError::Malformed(format!("unsupported wire version {}", bytes[1])));
    }
    let n_tensors = u16::from_le_bytes([bytes[2], bytes[3]]) as usize;
    let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
    let hdr = bytes
        .get(8..8 + hlen)
        .ok_or_else(|| RpcError::Malformed("truncated v2 header".into()))?;
    let text = std::str::from_utf8(hdr)
        .map_err(|e| RpcError::Malformed(format!("non-utf8 v2 header: {e}")))?;
    let v = json::parse(text).map_err(|e| RpcError::Malformed(e.to_string()))?;
    Ok((v, n_tensors, 8 + hlen))
}

/// Parse only the control header of a v2 payload; the tensor sections
/// are left untouched. A JSON-forced server uses this to learn the
/// request id it must refuse without paying a potentially tens-of-MB
/// section decode for a frame it will discard.
pub fn decode_binary_header(bytes: &[u8]) -> Result<Value, RpcError> {
    if bytes.first() != Some(&BIN_MAGIC) {
        return Err(RpcError::Malformed("not a v2 payload".into()));
    }
    decode_v2_preamble(bytes).map(|(v, _, _)| v)
}

/// Walk and validate the tensor-section table of a v2 payload — shared by
/// the materializing and zero-copy decodes so their error behavior cannot
/// diverge. Returns the parsed header plus per-section shape/offset metas;
/// no tensor data is touched beyond bounds checks.
fn parse_v2(bytes: &[u8]) -> Result<(Value, Vec<Section>), RpcError> {
    let (v, n_tensors, mut off) = decode_v2_preamble(bytes)?;
    let mut sections = Vec::with_capacity(n_tensors.min(64));
    for i in 0..n_tensors {
        let dims = bytes
            .get(off..off + 8)
            .ok_or_else(|| RpcError::Malformed(format!("truncated tensor section {i}")))?;
        let rows = u32::from_le_bytes([dims[0], dims[1], dims[2], dims[3]]) as usize;
        let cols = u32::from_le_bytes([dims[4], dims[5], dims[6], dims[7]]) as usize;
        off += 8;
        let nbytes = tensor_byte_len(rows, cols)?;
        if bytes.get(off..off + nbytes).is_none() {
            return Err(RpcError::Malformed(format!("truncated tensor section {i}")));
        }
        sections.push(Section { rows, cols, off });
        off += nbytes;
    }
    if off != bytes.len() {
        return Err(RpcError::Malformed(format!(
            "{} trailing bytes after tensor sections",
            bytes.len() - off
        )));
    }
    Ok((v, sections))
}

fn section_mat(bytes: &[u8], s: &Section) -> Mat {
    let data = &bytes[s.off..s.off + s.rows * s.cols * 4];
    let mut vals = Vec::with_capacity(s.rows * s.cols);
    for ch in data.chunks_exact(4) {
        vals.push(f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
    }
    Mat::from_vec(vals, s.rows, s.cols)
}

/// Decode frame-payload bytes, auto-detecting v1 JSON vs v2 binary by the
/// magic byte. Returns the envelope, the tensor sections (empty for v1),
/// and which encoding arrived. Every section is materialized; hot paths
/// use [`decode_frame`] instead and materialize per consumed field.
pub fn decode_payload(bytes: &[u8]) -> Result<(Value, Vec<Mat>, WireMode), RpcError> {
    if bytes.first() != Some(&BIN_MAGIC) {
        let text = std::str::from_utf8(bytes)
            .map_err(|e| RpcError::Malformed(format!("non-utf8 frame: {e}")))?;
        let v = json::parse(text).map_err(|e| RpcError::Malformed(e.to_string()))?;
        return Ok((v, Vec::new(), WireMode::Json));
    }
    let (v, sections) = parse_v2(bytes)?;
    let tensors = sections.iter().map(|s| section_mat(bytes, s)).collect();
    Ok((v, tensors, WireMode::Binary))
}

/// Zero-copy decode: like [`decode_payload`], but the returned
/// [`TensorBuf`] takes ownership of the frame bytes and serves borrowed
/// [`MatView`]s instead of materializing every section up front. The
/// section table is fully validated here (truncation, size caps, trailing
/// bytes), so views can slice without further checks.
pub fn decode_frame(bytes: Vec<u8>) -> Result<(Value, TensorBuf, WireMode), RpcError> {
    if bytes.first() != Some(&BIN_MAGIC) {
        let text = std::str::from_utf8(&bytes)
            .map_err(|e| RpcError::Malformed(format!("non-utf8 frame: {e}")))?;
        let v = json::parse(text).map_err(|e| RpcError::Malformed(e.to_string()))?;
        return Ok((v, TensorBuf::empty(), WireMode::Json));
    }
    let (v, sections) = parse_v2(&bytes)?;
    Ok((v, TensorBuf { buf: bytes, sections }, WireMode::Binary))
}

/// `hello {wire, version, mux?}` reply: binary is agreed only when the
/// peer asked for it and this server's config allows it. Request-id
/// multiplexing is echoed (`mux: true`) only when the peer requested it,
/// `server_mux` enables it, *and* the agreed wire is binary — so
/// `v2+mux` implies v2, and pre-mux peers (which never send the key)
/// negotiate exactly as before (DESIGN.md §Wire negotiation matrix).
pub fn hello_reply(params: &Value, server: WireMode, server_mux: bool) -> Value {
    let requested = params.get("wire").and_then(Value::as_str).unwrap_or("binary");
    let agreed = if requested == "binary" && server == WireMode::Binary {
        WireMode::Binary
    } else {
        WireMode::Json
    };
    let mut m = Map::new();
    m.insert("wire", Value::from(agreed.as_str()));
    m.insert("version", Value::from(WIRE_VERSION as u64));
    if server_mux
        && agreed == WireMode::Binary
        && params.get("mux").and_then(Value::as_bool) == Some(true)
    {
        m.insert("mux", Value::Bool(true));
    }
    Value::Object(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::value::obj;
    use crate::util::rng::Rng;

    fn bits(m: &Mat) -> Vec<u32> {
        m.as_slice().iter().map(|x| x.to_bits()).collect()
    }

    fn roundtrip_binary(env: &Value, tensors: &[Mat]) -> (Value, Vec<Mat>) {
        let bytes = encode_payload(env, tensors, WireMode::Binary).unwrap();
        let (v, t, mode) = decode_payload(&bytes).unwrap();
        assert_eq!(mode, WireMode::Binary);
        (v, t)
    }

    #[test]
    fn binary_roundtrip_preserves_nan_and_inf_bits() {
        let m = Mat::from_vec(
            vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 1.5e-42, 3.25],
            2,
            3,
        );
        let env = obj([("m", placeholder(0))]);
        let (v, t) = roundtrip_binary(&env, &[m.clone()]);
        assert_eq!(v, env);
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].shape(), (2, 3));
        assert_eq!(bits(&t[0]), bits(&m), "f32 bits must survive the binary wire");
        // the JSON wire keeps the v1 convention: non-finite becomes null,
        // which decodes back as NaN
        let bytes = encode_payload(&env, &[m], WireMode::Json).unwrap();
        let (v, t, mode) = decode_payload(&bytes).unwrap();
        assert_eq!(mode, WireMode::Json);
        assert!(t.is_empty());
        let back = resolve_mat(v.get("m").unwrap(), &t).unwrap();
        assert!(back.get(0, 0).is_nan());
        assert!(back.get(0, 1).is_nan(), "inf is null on the json wire");
        assert_eq!(back.get(1, 2), 3.25);
    }

    #[test]
    fn empty_tensors_roundtrip() {
        for (r, c) in [(0, 0), (0, 5), (5, 0)] {
            let m = Mat::zeros(r, c);
            let env = obj([("m", placeholder(0))]);
            let (_, t) = roundtrip_binary(&env, &[m]);
            assert_eq!(t[0].shape(), (r, c), "{r}x{c}");
        }
        // zero sections is also fine
        let (v, t) = roundtrip_binary(&obj([("x", Value::from(1i64))]), &[]);
        assert!(t.is_empty());
        assert_eq!(v.get("x").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn truncated_tensor_section_rejected() {
        let m = Mat::from_vec(vec![1.0; 12], 3, 4);
        let bytes =
            encode_payload(&obj([("m", placeholder(0))]), &[m], WireMode::Binary).unwrap();
        // chop anywhere inside the tensor region: header stays parseable,
        // the section must fail loudly
        for cut in [bytes.len() - 1, bytes.len() - 17, bytes.len() - 48 + 7] {
            let err = decode_payload(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(&err, RpcError::Malformed(e) if e.contains("truncated")),
                "cut at {cut}: {err}"
            );
        }
        // trailing junk is also a framing error
        let mut fat = bytes.clone();
        fat.extend_from_slice(&[0u8; 3]);
        let err = decode_payload(&fat).unwrap_err();
        assert!(matches!(&err, RpcError::Malformed(e) if e.contains("trailing")), "{err}");
    }

    #[test]
    fn oversized_section_rejected_on_both_sides() {
        // decode side: a forged header claiming a huge tensor must be
        // rejected from the 8 dim bytes alone, before any allocation
        let mut bytes = vec![BIN_MAGIC, WIRE_VERSION, 1, 0];
        bytes.extend_from_slice(&2u32.to_le_bytes()); // header "{}"
        bytes.extend_from_slice(b"{}");
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(decode_payload(&bytes), Err(RpcError::FrameTooLarge(_))));

        // encode side: a real tensor over MAX_FRAME never reaches the wire
        let m = Mat::zeros(MAX_FRAME / 4 + 1, 1);
        assert!(matches!(
            encode_payload(&Value::Null, &[m], WireMode::Binary),
            Err(RpcError::FrameTooLarge(_))
        ));
    }

    #[test]
    fn bad_magic_version_and_short_frames_rejected() {
        assert!(matches!(
            decode_payload(&[BIN_MAGIC, 9, 0, 0, 0, 0, 0, 0]),
            Err(RpcError::Malformed(_))
        ));
        assert!(matches!(decode_payload(&[BIN_MAGIC, WIRE_VERSION, 1]), Err(RpcError::Malformed(_))));
        // plain JSON still parses
        let (v, t, mode) = decode_payload(b"{\"a\":1}").unwrap();
        assert_eq!(mode, WireMode::Json);
        assert!(t.is_empty());
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        // junk is neither
        assert!(decode_payload(&[0xFF, 0xFE]).is_err());
    }

    #[test]
    fn header_only_decode_skips_sections() {
        let m = Mat::from_vec(vec![1.0; 8], 2, 4);
        let mut p = Payload::default();
        let ph = p.stash_mat(m);
        let env = obj([("id", Value::from(9i64)), ("params", obj([("emb", ph)]))]);
        let bytes = encode_payload(&env, &p.tensors, WireMode::Binary).unwrap();
        let v = decode_binary_header(&bytes).unwrap();
        assert_eq!(v.get("id").unwrap().as_i64(), Some(9));
        // a truncated tensor section doesn't matter on the header-only
        // path (a JSON-forced server only needs the id to refuse)
        let v = decode_binary_header(&bytes[..bytes.len() - 10]).unwrap();
        assert_eq!(v.get("id").unwrap().as_i64(), Some(9));
        // v1 payloads are not its business
        assert!(decode_binary_header(b"{}").is_err());
    }

    #[test]
    fn inline_value_resolves_nested_placeholders() {
        let mut p = Payload::default();
        let ph = p.stash_mat(Mat::from_vec(vec![1.0, 2.0], 1, 2));
        p.value = obj([("deep", Value::Array(vec![obj([("m", ph)])]))]);
        let flat = inline_value(&p.value, &p.tensors).unwrap();
        let inner = flat.get("deep").unwrap().idx(0).unwrap().get("m").unwrap();
        assert!(is_inline_mat(inner));
        assert_eq!(mat_from_value(inner).unwrap(), p.tensors[0]);
        // dangling ref is an error
        assert!(inline_value(&placeholder(5), &p.tensors).is_err());
    }

    /// Random JSON (finite numbers only, exact-int range) for header props.
    fn random_header(rng: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.below(2) == 0),
            2 => Value::from(rng.below(1_000_000) as i64 - 500_000),
            3 => Value::from(
                (0..rng.below(10))
                    .map(|_| b"ab\"\\\n\t {}[]:,$"[rng.below(14)] as char)
                    .collect::<String>(),
            ),
            4 => Value::Array(
                (0..rng.below(4)).map(|_| random_header(rng, depth - 1)).collect(),
            ),
            _ => {
                let mut m = Map::new();
                for i in 0..rng.below(4) {
                    m.insert(format!("k{i}"), random_header(rng, depth - 1));
                }
                Value::Object(m)
            }
        }
    }

    #[test]
    fn prop_binary_roundtrip_over_random_payloads() {
        crate::util::prop::check("wire-binary-roundtrip", 60, |rng| {
            let header = random_header(rng, 3);
            let n_tensors = rng.below(4);
            let tensors: Vec<Mat> = (0..n_tensors)
                .map(|_| {
                    let (r, c) = (rng.below(12), 1 + rng.below(9));
                    let mut data: Vec<f32> =
                        (0..r * c).map(|_| rng.normal_f32()).collect();
                    if !data.is_empty() && rng.below(3) == 0 {
                        let i = rng.below(data.len());
                        data[i] = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY]
                            [rng.below(3)];
                    }
                    Mat::from_vec(data, r, c)
                })
                .collect();
            let bytes = encode_payload(&header, &tensors, WireMode::Binary)
                .map_err(|e| format!("encode: {e}"))?;
            let (v, t, mode) =
                decode_payload(&bytes).map_err(|e| format!("decode: {e}"))?;
            crate::prop_assert!(mode == WireMode::Binary, "mode {mode:?}");
            crate::prop_assert!(v == header, "header mismatch:\n got {v:?}\nwant {header:?}");
            crate::prop_assert!(t.len() == tensors.len(), "tensor count");
            for (a, b) in t.iter().zip(&tensors) {
                crate::prop_assert!(a.shape() == b.shape(), "shape mismatch");
                crate::prop_assert!(bits(a) == bits(b), "tensor bits mismatch");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_json_mode_matches_binary_for_finite_payloads() {
        crate::util::prop::check("wire-json-parity", 40, |rng| {
            let (r, c) = (1 + rng.below(20), 1 + rng.below(16));
            let m = Mat::from_vec(
                (0..r * c).map(|_| rng.normal_f32()).collect(),
                r,
                c,
            );
            let mut p = Payload::default();
            let ph = p.stash_mat(m.clone());
            p.value = obj([("m", ph)]);
            let env = obj([("params", p.value.clone())]);

            // binary wire
            let bb = encode_payload(&env, &p.tensors, WireMode::Binary)
                .map_err(|e| format!("{e}"))?;
            let (bv, bt, _) = decode_payload(&bb).map_err(|e| format!("{e}"))?;
            let bm = resolve_mat(bv.get("params").unwrap().get("m").unwrap(), &bt)
                .map_err(|e| e.to_string())?;

            // json wire (text round trip)
            let jb = encode_payload(&env, &p.tensors, WireMode::Json)
                .map_err(|e| format!("{e}"))?;
            let (jv, jt, _) = decode_payload(&jb).map_err(|e| format!("{e}"))?;
            let jm = resolve_mat(jv.get("params").unwrap().get("m").unwrap(), &jt)
                .map_err(|e| e.to_string())?;

            crate::prop_assert!(bits(&bm) == bits(&m), "binary not bit-exact");
            crate::prop_assert!(
                bits(&jm) == bits(&m),
                "json text round trip not exact for finite f32"
            );
            Ok(())
        });
    }

    #[test]
    fn binary_payload_is_at_least_3x_smaller_than_json() {
        // The acceptance bar from the rpc_wire bench, pinned as a
        // deterministic unit test: payload bytes are a pure function of
        // the data, no timing involved.
        let mut rng = Rng::new(42);
        let m = Mat::from_vec((0..1000 * 64).map(|_| rng.normal_f32()).collect(), 1000, 64);
        let mut p = Payload::default();
        let ph = p.stash_mat(m);
        let env = obj([("id", Value::from(1i64)), ("result", obj([("emb", ph)]))]);
        let json = encode_payload(&env, &p.tensors, WireMode::Json).unwrap();
        let bin = encode_payload(&env, &p.tensors, WireMode::Binary).unwrap();
        assert!(
            json.len() >= 3 * bin.len(),
            "json {} bytes vs binary {} bytes",
            json.len(),
            bin.len()
        );
    }

    #[test]
    fn decode_frame_views_match_materialized_decode() {
        let m0 = Mat::from_vec(vec![f32::NAN, 1.5, -2.25, 0.0, 7.0, -0.0], 2, 3);
        let m1 = Mat::from_vec(vec![3.5; 8], 4, 2);
        let env = obj([("a", placeholder(0)), ("b", placeholder(1))]);
        let bytes =
            encode_payload(&env, &[m0.clone(), m1.clone()], WireMode::Binary).unwrap();
        let (v_full, mats, _) = decode_payload(&bytes).unwrap();
        let (v, tb, mode) = decode_frame(bytes).unwrap();
        assert_eq!(mode, WireMode::Binary);
        assert_eq!(v, v_full);
        assert_eq!(tb.len(), 2);
        for (i, want) in mats.iter().enumerate() {
            let view = tb.view(i).unwrap();
            assert_eq!(view.shape(), want.shape());
            assert_eq!(bits(&view.to_mat()), bits(want), "section {i} bits");
            for r in 0..want.rows() {
                assert_eq!(
                    view.row_vec(r).iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    want.row(r).iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "section {i} row {r}"
                );
            }
        }
        // element access decodes at arbitrary (unaligned) offsets
        assert_eq!(tb.view(1).unwrap().get(3, 1), 3.5);
        assert!(tb.view(2).is_none());
        // materialize reproduces the eager decode exactly (bitwise — the
        // NaN payload makes PartialEq useless here)
        let mzd = tb.materialize();
        assert_eq!(mzd.len(), mats.len());
        for (a, b) in mzd.iter().zip(&mats) {
            assert_eq!(bits(a), bits(b));
        }
    }

    #[test]
    fn decode_frame_rejects_what_decode_payload_rejects() {
        let m = Mat::from_vec(vec![1.0; 12], 3, 4);
        let bytes =
            encode_payload(&obj([("m", placeholder(0))]), &[m], WireMode::Binary).unwrap();
        for cut in [bytes.len() - 1, bytes.len() - 17] {
            assert!(matches!(
                decode_frame(bytes[..cut].to_vec()),
                Err(RpcError::Malformed(e)) if e.contains("truncated")
            ));
        }
        let mut fat = bytes.clone();
        fat.extend_from_slice(&[0u8; 2]);
        assert!(matches!(
            decode_frame(fat),
            Err(RpcError::Malformed(e)) if e.contains("trailing")
        ));
        // v1 text still decodes with no sections
        let (v, tb, mode) = decode_frame(b"{\"a\":4}".to_vec()).unwrap();
        assert_eq!(mode, WireMode::Json);
        assert!(tb.is_empty());
        assert_eq!(v.get("a").unwrap().as_i64(), Some(4));
    }

    #[test]
    fn body_resolves_placeholder_inline_and_label_forms() {
        let m = Mat::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        let env = obj([
            ("emb", placeholder(0)),
            ("inline", mat_to_value(&m)),
            ("labels", Value::Array(vec![Value::from(1i64), Value::from(0i64)])),
        ]);
        let bytes = encode_payload(&env, &[m.clone()], WireMode::Binary).unwrap();
        let (value, tensors, _) = decode_frame(bytes).unwrap();
        let body = Body { value, tensors };
        // placeholder resolves through a view, inline through an owned Mat
        assert_eq!(body.mat("emb").unwrap().unwrap(), m);
        assert!(matches!(body.mat_ref("emb").unwrap().unwrap(), MatRef::View(_)));
        assert_eq!(body.mat("inline").unwrap().unwrap(), m);
        assert!(matches!(body.mat_ref("inline").unwrap().unwrap(), MatRef::Owned(_)));
        assert!(body.mat("absent").unwrap().is_none());
        // maybe_mat: matrix forms yes, plain arrays no
        let labels = body.value.get("labels").unwrap().clone();
        assert!(body.maybe_mat(&labels).unwrap().is_none());
        let ph = body.value.get("emb").unwrap().clone();
        assert_eq!(body.maybe_mat(&ph).unwrap().unwrap(), m);
        // a dangling ref is an error, mirroring resolve_mat
        assert!(Body::json(obj([("x", placeholder(7))])).mat("x").is_err());
        // row access goes straight to the frame buffer
        let r = body.mat_ref("emb").unwrap().unwrap();
        assert_eq!(r.row_vec(1), &[3.0, 4.0]);
        assert_eq!((r.rows(), r.cols()), (2, 2));
        // the owned views keep the v1-compatible shapes
        let p = body.to_payload();
        assert_eq!(p.tensors.len(), 1);
        assert_eq!(p.tensors[0], m);
    }

    #[test]
    fn hello_reply_negotiates() {
        let req = obj([("wire", Value::from("binary"))]);
        let r = hello_reply(&req, WireMode::Binary, false);
        assert_eq!(r.get("wire").unwrap().as_str(), Some("binary"));
        assert_eq!(r.get("version").unwrap().as_i64(), Some(WIRE_VERSION as i64));
        // a mux-less exchange never grows the key (old peers see the
        // exact pre-mux reply shape)
        assert!(r.get("mux").is_none());
        // server forced to json refuses
        let r = hello_reply(&req, WireMode::Json, false);
        assert_eq!(r.get("wire").unwrap().as_str(), Some("json"));
        // client asking for json gets json even from a binary server
        let r = hello_reply(&obj([("wire", Value::from("json"))]), WireMode::Binary, false);
        assert_eq!(r.get("wire").unwrap().as_str(), Some("json"));
    }

    #[test]
    fn hello_reply_mux_negotiation_matrix() {
        let mux_req =
            obj([("wire", Value::from("binary")), ("mux", Value::Bool(true))]);
        // requested + enabled + binary agreed => mux on
        let r = hello_reply(&mux_req, WireMode::Binary, true);
        assert_eq!(r.get("wire").unwrap().as_str(), Some("binary"));
        assert_eq!(r.get("mux").unwrap().as_bool(), Some(true));
        // server has mux disabled: silently classic (no key at all)
        let r = hello_reply(&mux_req, WireMode::Binary, false);
        assert!(r.get("mux").is_none());
        // peer never asked (old peer): no echo even when enabled
        let r = hello_reply(&obj([("wire", Value::from("binary"))]), WireMode::Binary, true);
        assert!(r.get("mux").is_none());
        // JSON-agreed wire never muxes: v2+mux implies v2
        let r = hello_reply(&mux_req, WireMode::Json, true);
        assert_eq!(r.get("wire").unwrap().as_str(), Some("json"));
        assert!(r.get("mux").is_none());
    }
}

//! # ALaaS — Active-Learning-as-a-Service
//!
//! Rust + JAX + Pallas reproduction of *"Active-Learning-as-a-Service: An
//! Automatic and Efficient MLOps System for Data-Centric AI"* (2022).
//!
//! Three-layer architecture (DESIGN.md):
//! * **L3 (this crate)** — the coordinator: AL server/client, stage-level
//!   pipeline, dynamic batching, data cache, strategy zoo, PSHEA agent.
//! * **L2/L1 (python/compile, build-time only)** — JAX model + Pallas
//!   kernels, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **runtime** — loads the artifacts through the `xla` crate's PJRT CPU
//!   client; Python never runs on the request path.

pub mod agent;
pub mod baselines;
pub mod cache;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod data;
pub mod durable;
pub mod json;
pub mod store;
pub mod metrics;
pub mod trace;
pub mod pipeline;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod strategies;
pub mod trainer;
pub mod uri;
pub mod util;
pub mod yamlmini;

//! Dynamic batcher: aggregate a stream of single samples into inference
//! batches (paper §3.3 "batching"; the serving-systems lineage is Clipper
//! [Crankshaw '17]).
//!
//! Policy: dispatch when `max_batch` samples are waiting, or when the
//! oldest waiting sample has waited `max_wait` (so a trickle of samples
//! still makes progress). A full batch is always preferred — the batcher
//! only sleeps when the queue is drained.

use std::time::{Duration, Instant};

use crate::util::chan::{Receiver, Sender};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(20) }
    }
}

/// Pump items from `rx` into batches on `tx` until `rx` closes. Preserves
/// arrival order within and across batches. Returns the number of batches
/// emitted.
pub fn run_batcher<T: Send>(
    rx: &Receiver<T>,
    tx: &Sender<Vec<T>>,
    policy: BatchPolicy,
) -> usize {
    assert!(policy.max_batch >= 1);
    let mut emitted = 0usize;
    let mut pending: Vec<T> = Vec::with_capacity(policy.max_batch);
    let mut oldest: Option<Instant> = None;
    loop {
        // how long may we still wait for the current partial batch?
        let wait_left = match oldest {
            Some(t0) => policy.max_wait.saturating_sub(t0.elapsed()),
            None => Duration::from_secs(3600), // nothing pending: wait long
        };
        let item = if pending.len() >= policy.max_batch {
            None // dispatch immediately, don't consume more
        } else {
            match rx.recv_timeout(wait_left) {
                Ok(Some(v)) => Some(v),
                Ok(None) => {
                    // input closed: flush and stop
                    if !pending.is_empty() {
                        let _ = tx.send(std::mem::take(&mut pending));
                        emitted += 1;
                    }
                    return emitted;
                }
                Err(()) => None, // timed out with a partial batch
            }
        };
        match item {
            Some(v) => {
                if pending.is_empty() {
                    oldest = Some(Instant::now());
                }
                pending.push(v);
                if pending.len() >= policy.max_batch {
                    if tx.send(std::mem::replace(
                        &mut pending,
                        Vec::with_capacity(policy.max_batch),
                    ))
                    .is_err()
                    {
                        return emitted;
                    }
                    emitted += 1;
                    oldest = None;
                }
            }
            None => {
                // timeout (or full): flush partial batch
                if !pending.is_empty() {
                    if tx
                        .send(std::mem::replace(
                            &mut pending,
                            Vec::with_capacity(policy.max_batch),
                        ))
                        .is_err()
                    {
                        return emitted;
                    }
                    emitted += 1;
                    oldest = None;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::chan::bounded;

    #[test]
    fn full_batches_dispatch_eagerly() {
        let (tx_in, rx_in) = bounded(64);
        let (tx_out, rx_out) = bounded(64);
        for i in 0..10 {
            tx_in.send(i).unwrap();
        }
        drop(tx_in);
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) };
        let n = run_batcher(&rx_in, &tx_out, policy);
        assert_eq!(n, 3);
        assert_eq!(rx_out.recv().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(rx_out.recv().unwrap(), vec![4, 5, 6, 7]);
        assert_eq!(rx_out.recv().unwrap(), vec![8, 9]); // closing flush
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let (tx_in, rx_in) = bounded(8);
        let (tx_out, rx_out) = bounded::<Vec<i32>>(8);
        let policy = BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(30) };
        let h = std::thread::spawn(move || run_batcher(&rx_in, &tx_out, policy));
        tx_in.send(1).unwrap();
        tx_in.send(2).unwrap();
        // don't close; the batcher must flush on timeout
        let batch = rx_out.recv().expect("timed-out flush");
        assert_eq!(batch, vec![1, 2]);
        drop(tx_in);
        h.join().unwrap();
    }

    #[test]
    fn prop_batches_partition_the_stream() {
        crate::util::prop::check("batcher-partition", 30, |rng| {
            let n = rng.below(500);
            let max_batch = 1 + rng.below(33);
            let (tx_in, rx_in) = bounded(64);
            let (tx_out, rx_out) = bounded(1024);
            let items: Vec<u64> = (0..n as u64).collect();
            let feeder = {
                let items = items.clone();
                std::thread::spawn(move || {
                    for i in items {
                        tx_in.send(i).unwrap();
                    }
                })
            };
            let policy = BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(1),
            };
            let emitted = run_batcher(&rx_in, &tx_out, policy);
            feeder.join().unwrap();
            drop(tx_out);
            let mut got = Vec::new();
            let mut batches = 0;
            while let Some(b) = rx_out.recv() {
                prop_assert!(!b.is_empty(), "empty batch emitted");
                prop_assert!(b.len() <= max_batch, "batch over max: {}", b.len());
                got.extend(b);
                batches += 1;
            }
            prop_assert!(batches == emitted, "emitted count mismatch");
            prop_assert!(got == items, "stream not preserved in order");
            Ok(())
        });
    }

    #[test]
    fn receiver_drop_stops_batcher() {
        let (tx_in, rx_in) = bounded(8);
        let (tx_out, rx_out) = bounded::<Vec<i32>>(1);
        drop(rx_out);
        for i in 0..8 {
            tx_in.send(i).unwrap();
        }
        drop(tx_in);
        let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) };
        // must return (not hang/panic) even though the output is gone
        let _ = run_batcher(&rx_in, &tx_out, policy);
    }
}

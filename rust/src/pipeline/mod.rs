//! The processing pipeline (paper §3.3, Figure 3) — ALaaS's efficiency
//! contribution.
//!
//! Three stages: **fetch** (download from the object store, through the
//! data cache), **preprocess** (decode + normalize), **infer** (embedding
//! + uncertainty scores through the compute backend, dynamically batched).
//!
//! Three dataflows, matching Figure 3 exactly:
//! * [`DataflowMode::SerialOneShot`] (3a) — every stage runs to completion
//!   over the whole pool before the next starts (DeepAL/ModAL-style).
//! * [`DataflowMode::SerialPerRound`] (3b) — the pool is split into rounds
//!   processed serially (libact/ALiPy-style).
//! * [`DataflowMode::Pipelined`] (3c) — ALaaS: all stages run
//!   concurrently, connected by bounded queues; a sample can be inferred
//!   while later samples are still downloading. The bounded queues are the
//!   backpressure (a fast fetcher cannot flood memory).

mod batcher;
mod run;

pub use batcher::{run_batcher, BatchPolicy};
pub use run::{run_pipeline, PipelineError, PipelineOutput, PipelineParams};

/// Figure 3's three dataflows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataflowMode {
    /// (a) stage-serial over the whole dataset.
    SerialOneShot,
    /// (b) stage-serial within each of `n` rounds.
    SerialPerRound(usize),
    /// (c) stage-level parallelism (ALaaS).
    Pipelined,
}

impl DataflowMode {
    pub fn label(&self) -> String {
        match self {
            DataflowMode::SerialOneShot => "serial-oneshot".into(),
            DataflowMode::SerialPerRound(n) => format!("serial-{n}rounds"),
            DataflowMode::Pipelined => "pipelined".into(),
        }
    }
}

//! Pipeline engine: executes a dataset scan under any of the Figure 3
//! dataflows and returns per-sample embeddings + uncertainty scores.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::batcher::{run_batcher, BatchPolicy};
use super::DataflowMode;
use crate::cache::DataCache;
use crate::data::decode_image;
use crate::metrics::Registry;
use crate::runtime::backend::{ComputeBackend, NUM_SCORES};
use crate::store::{SampleRef, StoreRouter};
use crate::trainer::LinearHead;
use crate::uri::Uri;
use crate::util::chan::bounded;
use crate::util::mat::Mat;

/// Pipeline run parameters (per-stage parallelism + batching policy).
#[derive(Debug, Clone)]
pub struct PipelineParams {
    pub mode: DataflowMode,
    pub fetch_threads: usize,
    pub preprocess_threads: usize,
    /// Concurrent inference dispatchers (>= PJRT replicas to keep every
    /// worker busy).
    pub infer_threads: usize,
    /// Bounded queue capacity between stages (backpressure).
    pub queue_depth: usize,
    pub batch: BatchPolicy,
    /// Injected per-item preprocess overhead — used by the Table 2
    /// baseline tool profiles (pure-Python per-sample dispatch cost).
    pub per_item_overhead: Duration,
    /// Injected per-round overhead (model reload in per-round tools).
    pub per_round_overhead: Duration,
}

impl Default for PipelineParams {
    fn default() -> Self {
        PipelineParams {
            mode: DataflowMode::Pipelined,
            fetch_threads: 4,
            preprocess_threads: 2,
            infer_threads: 2,
            queue_depth: 256,
            batch: BatchPolicy::default(),
            per_item_overhead: Duration::ZERO,
            per_round_overhead: Duration::ZERO,
        }
    }
}

/// What a scan produces: one row per input sample, input order.
#[derive(Debug)]
pub struct PipelineOutput {
    pub embeddings: Mat,
    pub scores: Mat,
    /// (input index, error) for samples that failed any stage; their rows
    /// are zero. The AL layer excludes them from selection.
    pub errors: Vec<(usize, String)>,
    pub elapsed: Duration,
    /// Successfully processed sample count.
    pub processed: usize,
}

/// Fatal pipeline failure (per-sample failures land in `errors` instead).
#[derive(Debug, thiserror::Error)]
pub enum PipelineError {
    #[error("runtime: {0}")]
    Runtime(#[from] crate::runtime::backend::RuntimeError),
    #[error("pipeline internal: {0}")]
    Internal(String),
}

/// A sample moving between stages.
struct Ready {
    idx: usize,
    tensor: Arc<Vec<f32>>,
}

/// Run a scan over `samples`. See module docs for the modes.
#[allow(clippy::too_many_arguments)]
pub fn run_pipeline(
    samples: &[SampleRef],
    store: &StoreRouter,
    cache: &DataCache,
    backend: &Arc<dyn ComputeBackend>,
    head: &LinearHead,
    params: &PipelineParams,
    metrics: Option<&Arc<Registry>>,
) -> Result<PipelineOutput, PipelineError> {
    let t0 = Instant::now();
    let d = {
        // probe embedding width with a zero image once (cheap on host; one
        // padded batch on pjrt) — avoids hardcoding D here.
        let probe = Mat::zeros(1, crate::data::IMG_DIM);
        backend.embed(&probe)?.cols()
    };
    let n = samples.len();
    let out = Mutex::new((Mat::zeros(n, d), Mat::zeros(n, NUM_SCORES)));
    let errors = Mutex::new(Vec::new());
    let processed = std::sync::atomic::AtomicUsize::new(0);

    match params.mode {
        DataflowMode::Pipelined => run_pipelined(
            samples, store, cache, backend, head, params, metrics, &out, &errors, &processed,
        )?,
        DataflowMode::SerialOneShot => run_serial(
            samples, store, cache, backend, head, params, metrics, &out, &errors, &processed,
        )?,
        DataflowMode::SerialPerRound(rounds) => {
            let rounds = rounds.max(1);
            let chunk = n.div_ceil(rounds);
            for (r, part) in samples.chunks(chunk.max(1)).enumerate() {
                if !params.per_round_overhead.is_zero() {
                    std::thread::sleep(params.per_round_overhead);
                }
                let base = r * chunk;
                run_serial_offset(
                    part, base, store, cache, backend, head, params, metrics, &out, &errors,
                    &processed,
                )?;
            }
        }
    }

    let (embeddings, scores) = out.into_inner().unwrap();
    let mut errs = errors.into_inner().unwrap();
    errs.sort_by_key(|(i, _)| *i);
    let elapsed = t0.elapsed();
    if let Some(m) = metrics {
        m.meter("pipeline.samples").add(n as u64);
        m.time("pipeline.scan", elapsed);
    }
    Ok(PipelineOutput {
        embeddings,
        scores,
        errors: errs,
        elapsed,
        processed: processed.load(std::sync::atomic::Ordering::Relaxed),
    })
}

/// Fetch one sample through the cache; returns the preprocessed tensor.
fn fetch_and_preprocess(
    s: &SampleRef,
    store: &StoreRouter,
    cache: &DataCache,
    overhead: Duration,
    metrics: Option<&Arc<Registry>>,
) -> Result<Arc<Vec<f32>>, String> {
    if let Some(t) = cache.get(&s.uri) {
        if let Some(m) = metrics {
            m.counter("cache.hits").fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        return Ok(t);
    }
    if let Some(m) = metrics {
        m.counter("cache.misses").fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
    let uri = Uri::parse(&s.uri).map_err(|e| e.to_string())?;
    let t_fetch = Instant::now();
    let raw = store.get(&uri).map_err(|e| e.to_string())?;
    if let Some(m) = metrics {
        m.time("stage.fetch", t_fetch.elapsed());
    }
    let t_pre = Instant::now();
    if !overhead.is_zero() {
        std::thread::sleep(overhead);
    }
    let px = decode_image(&raw).map_err(|e| e.to_string())?;
    let tensor = Arc::new(px);
    cache.put(&s.uri, tensor.clone());
    if let Some(m) = metrics {
        m.time("stage.preprocess", t_pre.elapsed());
    }
    Ok(tensor)
}

/// Infer one assembled batch and scatter rows into the output. `scratch`
/// is a per-worker buffer for the flattened batch, reused across calls so
/// the steady state allocates nothing (its capacity is reclaimed via
/// `Mat::into_vec` after the forward pass).
#[allow(clippy::too_many_arguments)]
fn infer_batch(
    batch: &[Ready],
    backend: &Arc<dyn ComputeBackend>,
    head: &LinearHead,
    out: &Mutex<(Mat, Mat)>,
    errors: &Mutex<Vec<(usize, String)>>,
    processed: &std::sync::atomic::AtomicUsize,
    metrics: Option<&Arc<Registry>>,
    scratch: &mut Vec<f32>,
) {
    let t0 = Instant::now();
    let img_dim = batch[0].tensor.len();
    let mut flat = std::mem::take(scratch);
    flat.clear();
    flat.reserve(batch.len() * img_dim);
    for r in batch {
        flat.extend_from_slice(&r.tensor);
    }
    let m = Mat::from_vec(flat, batch.len(), img_dim);
    match backend.forward(&m, &head.w, &head.b) {
        Ok((emb, sc)) => {
            let mut g = out.lock().unwrap();
            for (row, r) in batch.iter().enumerate() {
                g.0.row_mut(r.idx).copy_from_slice(emb.row(row));
                g.1.row_mut(r.idx).copy_from_slice(sc.row(row));
            }
            processed.fetch_add(batch.len(), std::sync::atomic::Ordering::Relaxed);
        }
        Err(e) => {
            let mut g = errors.lock().unwrap();
            for r in batch {
                g.push((r.idx, format!("infer: {e}")));
            }
        }
    }
    if let Some(mreg) = metrics {
        mreg.time("stage.infer", t0.elapsed());
        mreg.meter("infer.images").add(batch.len() as u64);
    }
    *scratch = m.into_vec();
}

/// Figure 3c: all stages concurrent, bounded queues in between.
#[allow(clippy::too_many_arguments)]
fn run_pipelined(
    samples: &[SampleRef],
    store: &StoreRouter,
    cache: &DataCache,
    backend: &Arc<dyn ComputeBackend>,
    head: &LinearHead,
    params: &PipelineParams,
    metrics: Option<&Arc<Registry>>,
    out: &Mutex<(Mat, Mat)>,
    errors: &Mutex<Vec<(usize, String)>>,
    processed: &std::sync::atomic::AtomicUsize,
) -> Result<(), PipelineError> {
    let (work_tx, work_rx) = bounded::<usize>(params.queue_depth);
    let (ready_tx, ready_rx) = bounded::<Ready>(params.queue_depth);
    let (batch_tx, batch_rx) = bounded::<Vec<Ready>>(params.queue_depth.max(4));

    std::thread::scope(|s| {
        // feeder
        s.spawn(move || {
            for i in 0..samples.len() {
                if work_tx.send(i).is_err() {
                    break;
                }
            }
            work_tx.close();
        });
        // fetch+preprocess workers (the cache collapses the two stages for
        // hits; misses pay download + decode)
        let n_fetch = (params.fetch_threads + params.preprocess_threads).max(1);
        for _ in 0..n_fetch {
            let work_rx = work_rx.clone();
            let ready_tx = ready_tx.clone();
            s.spawn(move || {
                while let Some(i) = work_rx.recv() {
                    match fetch_and_preprocess(
                        &samples[i],
                        store,
                        cache,
                        params.per_item_overhead,
                        metrics,
                    ) {
                        Ok(tensor) => {
                            if ready_tx.send(Ready { idx: i, tensor }).is_err() {
                                break;
                            }
                        }
                        Err(e) => errors.lock().unwrap().push((i, e)),
                    }
                }
            });
        }
        drop(ready_tx);
        drop(work_rx);
        // batcher
        {
            let batch_tx = batch_tx.clone();
            let policy = params.batch;
            s.spawn(move || {
                run_batcher(&ready_rx, &batch_tx, policy);
                batch_tx.close();
            });
        }
        drop(batch_tx);
        // infer workers
        for _ in 0..params.infer_threads.max(1) {
            let batch_rx = batch_rx.clone();
            s.spawn(move || {
                let mut scratch = Vec::new();
                while let Some(batch) = batch_rx.recv() {
                    if batch.is_empty() {
                        continue;
                    }
                    infer_batch(
                        &batch, backend, head, out, errors, processed, metrics,
                        &mut scratch,
                    );
                }
            });
        }
        drop(batch_rx);
    });
    Ok(())
}

/// Figure 3a: stage-serial (the baseline tools' dataflow).
#[allow(clippy::too_many_arguments)]
fn run_serial(
    samples: &[SampleRef],
    store: &StoreRouter,
    cache: &DataCache,
    backend: &Arc<dyn ComputeBackend>,
    head: &LinearHead,
    params: &PipelineParams,
    metrics: Option<&Arc<Registry>>,
    out: &Mutex<(Mat, Mat)>,
    errors: &Mutex<Vec<(usize, String)>>,
    processed: &std::sync::atomic::AtomicUsize,
) -> Result<(), PipelineError> {
    run_serial_offset(
        samples, 0, store, cache, backend, head, params, metrics, out, errors, processed,
    )
}

#[allow(clippy::too_many_arguments)]
fn run_serial_offset(
    samples: &[SampleRef],
    base: usize,
    store: &StoreRouter,
    cache: &DataCache,
    backend: &Arc<dyn ComputeBackend>,
    head: &LinearHead,
    params: &PipelineParams,
    metrics: Option<&Arc<Registry>>,
    out: &Mutex<(Mat, Mat)>,
    errors: &Mutex<Vec<(usize, String)>>,
    processed: &std::sync::atomic::AtomicUsize,
) -> Result<(), PipelineError> {
    // Stage 1+2 to completion (single-threaded, like the Python tools'
    // main loop), then stage 3 over fixed-size batches.
    let mut ready: Vec<Ready> = Vec::with_capacity(samples.len());
    for (off, s) in samples.iter().enumerate() {
        match fetch_and_preprocess(s, store, cache, params.per_item_overhead, metrics) {
            Ok(tensor) => ready.push(Ready { idx: base + off, tensor }),
            Err(e) => errors.lock().unwrap().push((base + off, e)),
        }
    }
    let mut scratch = Vec::new();
    for chunk in ready.chunks(params.batch.max_batch.max(1)) {
        infer_batch(chunk, backend, head, out, errors, processed, metrics, &mut scratch);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StoreConfig;
    use crate::data::{encode_image, IMG_DIM};
    use crate::runtime::backend::HostBackend;
    use crate::store::ObjectStore;
    use crate::util::rng::Rng;

    fn setup(n: usize) -> (Vec<SampleRef>, StoreRouter, DataCache, Arc<dyn ComputeBackend>) {
        let store = StoreRouter::new("/tmp", &StoreConfig {
            get_latency_us: 0,
            bandwidth_mib_s: 0.0,
            jitter: 0.0,
        });
        let mut rng = Rng::new(1);
        let mut samples = Vec::new();
        for i in 0..n {
            let img: Vec<f32> = (0..IMG_DIM).map(|_| rng.range_f32(-0.5, 0.5)).collect();
            let key = format!("ds/pool/img_{i:06}.bin");
            store.s3sim_backing().put(&key, &encode_image(&img)).unwrap();
            samples.push(SampleRef { id: i as u32, uri: format!("s3sim://{key}") });
        }
        let cache = DataCache::new(64 * 1024 * 1024, 4, true);
        let backend: Arc<dyn ComputeBackend> = Arc::new(HostBackend::new());
        (samples, store, cache, backend)
    }

    fn head() -> LinearHead {
        LinearHead::zeros(64, 10)
    }

    #[test]
    fn all_modes_produce_identical_results() {
        let (samples, store, cache, backend) = setup(40);
        let mut outputs = Vec::new();
        for mode in [
            DataflowMode::Pipelined,
            DataflowMode::SerialOneShot,
            DataflowMode::SerialPerRound(4),
        ] {
            // fresh (disabled) cache per mode so modes can't help each other
            let nocache = DataCache::new(0, 1, false);
            let params = PipelineParams { mode, ..Default::default() };
            let out = run_pipeline(
                &samples, &store, &nocache, &backend, &head(), &params, None,
            )
            .unwrap();
            assert!(out.errors.is_empty(), "{mode:?}: {:?}", out.errors);
            assert_eq!(out.processed, 40);
            outputs.push(out);
        }
        let base = &outputs[0];
        for o in &outputs[1..] {
            assert_eq!(base.embeddings, o.embeddings, "modes disagree on embeddings");
            assert_eq!(base.scores, o.scores, "modes disagree on scores");
        }
        let _ = cache;
    }

    #[test]
    fn rows_are_in_input_order() {
        let (samples, store, cache, backend) = setup(25);
        let params = PipelineParams::default();
        let out =
            run_pipeline(&samples, &store, &cache, &backend, &head(), &params, None).unwrap();
        // re-run single sample i and compare to row i
        for &i in &[0usize, 7, 24] {
            let one = run_pipeline(
                &samples[i..=i],
                &store,
                &cache,
                &backend,
                &head(),
                &params,
                None,
            )
            .unwrap();
            assert_eq!(out.embeddings.row(i), one.embeddings.row(0), "row {i}");
        }
    }

    #[test]
    fn cache_makes_second_scan_hit() {
        let (samples, store, cache, backend) = setup(30);
        let params = PipelineParams::default();
        let m = crate::metrics::Registry::new();
        run_pipeline(&samples, &store, &cache, &backend, &head(), &params, Some(&m)).unwrap();
        run_pipeline(&samples, &store, &cache, &backend, &head(), &params, Some(&m)).unwrap();
        assert_eq!(cache.misses(), 30, "first scan misses everything");
        assert!(cache.hits() >= 30, "second scan hits: {}", cache.hits());
    }

    #[test]
    fn store_fault_surfaces_as_sample_error_not_crash() {
        let (samples, store, cache, backend) = setup(20);
        store.s3sim().inject_fault(Some("img_000007".into()));
        let params = PipelineParams::default();
        let out =
            run_pipeline(&samples, &store, &cache, &backend, &head(), &params, None).unwrap();
        assert_eq!(out.errors.len(), 1);
        assert_eq!(out.errors[0].0, 7);
        assert_eq!(out.processed, 19);
        // failed row is zeroed
        assert!(out.embeddings.row(7).iter().all(|&v| v == 0.0));
        // other rows intact
        assert!(out.embeddings.row(8).iter().any(|&v| v != 0.0));
    }

    #[test]
    fn corrupt_blob_surfaces_as_sample_error() {
        let (mut samples, store, cache, backend) = setup(5);
        store.s3sim_backing().put("ds/bad.bin", &[1, 2, 3]).unwrap();
        samples.push(SampleRef { id: 99, uri: "s3sim://ds/bad.bin".into() });
        samples.push(SampleRef { id: 100, uri: "not a uri".into() });
        let params = PipelineParams::default();
        let out =
            run_pipeline(&samples, &store, &cache, &backend, &head(), &params, None).unwrap();
        assert_eq!(out.errors.len(), 2);
        assert_eq!(out.processed, 5);
    }

    #[test]
    fn pipelined_beats_serial_with_slow_store() {
        // Latency-bound store: overlap should win clearly.
        let store = StoreRouter::new("/tmp", &StoreConfig {
            get_latency_us: 4_000,
            bandwidth_mib_s: 0.0,
            jitter: 0.0,
        });
        let mut rng = Rng::new(2);
        let mut samples = Vec::new();
        for i in 0..60 {
            let img: Vec<f32> = (0..IMG_DIM).map(|_| rng.range_f32(-0.5, 0.5)).collect();
            let key = format!("ds/pool/img_{i:06}.bin");
            store.s3sim_backing().put(&key, &encode_image(&img)).unwrap();
            samples.push(SampleRef { id: i as u32, uri: format!("s3sim://{key}") });
        }
        let backend: Arc<dyn ComputeBackend> = Arc::new(HostBackend::new());
        let time_mode = |mode| {
            let cache = DataCache::new(0, 1, false);
            let params = PipelineParams { mode, fetch_threads: 8, ..Default::default() };
            let t0 = Instant::now();
            run_pipeline(&samples, &store, &cache, &backend, &head(), &params, None).unwrap();
            t0.elapsed()
        };
        let serial = time_mode(DataflowMode::SerialOneShot);
        let pipelined = time_mode(DataflowMode::Pipelined);
        // Debug-build inference is slow enough to mute some of the win;
        // the release-mode benches (table2) show the paper-scale gap.
        assert!(
            pipelined.as_secs_f64() < serial.as_secs_f64() * 0.75,
            "pipelined {pipelined:?} should clearly beat serial {serial:?}"
        );
    }

    #[test]
    fn empty_input_is_fine() {
        let (_, store, cache, backend) = setup(0);
        let params = PipelineParams::default();
        let out = run_pipeline(&[], &store, &cache, &backend, &head(), &params, None).unwrap();
        assert_eq!(out.processed, 0);
        assert_eq!(out.embeddings.rows(), 0);
    }
}

//! Multi-round AL experiment driver — the shared engine behind Fig 4a
//! (one-round strategy accuracy), Fig 5a (predictor evaluation) and
//! Fig 5b (PSHEA traces), and the `AlTask` implementation the agent runs.
//!
//! Each *arm* (strategy) owns an independent labeled set and head, exactly
//! like Algorithm 1's per-strategy state `d^l`: arms never share labels,
//! and every labeling is charged to the oracle.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::agent::AlTask;
use crate::data::{decode_image, Oracle};
use crate::runtime::backend::{ComputeBackend, RtResult};
use crate::strategies::{self, SelectCtx};
use crate::trainer::{self, EvalResult, LinearHead, TrainConfig};
use crate::util::mat::Mat;

/// One strategy's independent AL state.
struct Arm {
    /// Absolute pool indices labeled so far, in labeling order.
    labeled: Vec<usize>,
    head: LinearHead,
    accuracy: Vec<f64>,
}

/// The experiment: embedded splits + per-arm state.
pub struct AlExperiment {
    backend: Arc<dyn ComputeBackend>,
    pool_emb: Mat,
    init_emb: Mat,
    init_labels: Vec<u8>,
    test_emb: Mat,
    test_labels: Vec<u8>,
    oracle: Arc<Oracle>,
    /// Oracle ids of pool samples (index -> dataset id).
    pool_ids: Vec<u32>,
    num_classes: usize,
    pub train_cfg: TrainConfig,
    seed: u64,
    arms: BTreeMap<String, Arm>,
    /// Baseline head trained on the init split (Algorithm 1 line 5:
    /// "pre-train the deep active learning model"); computed once, every
    /// new arm starts from it so round-0 selection is informed.
    baseline_head: std::sync::OnceLock<(LinearHead, EvalResult)>,
}

impl AlExperiment {
    /// Build from pre-embedded splits (tests, benches with toy data).
    #[allow(clippy::too_many_arguments)]
    pub fn from_embeddings(
        backend: Arc<dyn ComputeBackend>,
        pool_emb: Mat,
        pool_ids: Vec<u32>,
        init_emb: Mat,
        init_labels: Vec<u8>,
        test_emb: Mat,
        test_labels: Vec<u8>,
        oracle: Arc<Oracle>,
        num_classes: usize,
        train_cfg: TrainConfig,
        seed: u64,
    ) -> Self {
        assert_eq!(pool_emb.rows(), pool_ids.len());
        assert_eq!(init_emb.rows(), init_labels.len());
        assert_eq!(test_emb.rows(), test_labels.len());
        AlExperiment {
            backend,
            pool_emb,
            init_emb,
            init_labels,
            test_emb,
            test_labels,
            oracle,
            pool_ids,
            num_classes,
            train_cfg,
            seed,
            arms: BTreeMap::new(),
            baseline_head: std::sync::OnceLock::new(),
        }
    }

    /// Build from a generated dataset: decode + embed all three splits
    /// through the backend (this is the expensive step; done once).
    pub fn from_generated(
        backend: Arc<dyn ComputeBackend>,
        gen: &crate::data::Generated,
        num_classes: usize,
        train_cfg: TrainConfig,
        seed: u64,
    ) -> RtResult<Self> {
        let n = gen.images.len();
        let n_init = gen.n_init;
        let n_pool = gen.n_pool;
        let embed_split = |lo: usize, hi: usize| -> RtResult<Mat> {
            let mut rows = Vec::with_capacity(hi - lo);
            for img in &gen.images[lo..hi] {
                rows.push(decode_image(img).expect("generated image decodes"));
            }
            let flat: Vec<f32> = rows.concat();
            let m = Mat::from_vec(flat, hi - lo, crate::data::IMG_DIM);
            backend.embed(&m)
        };
        let init_emb = embed_split(0, n_init)?;
        let pool_emb = embed_split(n_init, n_init + n_pool)?;
        let test_emb = embed_split(n_init + n_pool, n)?;
        let oracle = Arc::new(Oracle::from_labels(gen.labels.clone()));
        let init_ids: Vec<u32> = (0..n_init as u32).collect();
        let init_labels = oracle.label(&init_ids); // seed labels are paid for
        let pool_ids: Vec<u32> = (n_init as u32..(n_init + n_pool) as u32).collect();
        let test_ids: Vec<u32> = ((n_init + n_pool) as u32..n as u32).collect();
        let test_labels = oracle.eval_labels(&test_ids);
        Ok(Self::from_embeddings(
            backend,
            pool_emb,
            pool_ids,
            init_emb,
            init_labels,
            test_emb,
            test_labels,
            oracle,
            num_classes,
            train_cfg,
            seed,
        ))
    }

    pub fn pool_size(&self) -> usize {
        self.pool_emb.rows()
    }

    pub fn oracle(&self) -> &Arc<Oracle> {
        &self.oracle
    }

    /// Train the baseline head on the init split only (round-0 model,
    /// Algorithm 1 line 5). Cached: computed once per experiment.
    pub fn baseline(&self) -> RtResult<(LinearHead, EvalResult)> {
        if let Some((h, a)) = self.baseline_head.get() {
            return Ok((h.clone(), *a));
        }
        let (head, _) = trainer::fit(
            self.backend.as_ref(),
            &self.init_emb,
            &self.init_labels,
            self.num_classes,
            &self.train_cfg,
        )?;
        let acc =
            trainer::evaluate(self.backend.as_ref(), &head, &self.test_emb, &self.test_labels)?;
        let _ = self.baseline_head.set((head.clone(), acc));
        Ok((head, acc))
    }

    /// Upper bound: train on init + the whole pool ("entire dataset"
    /// baseline of Fig 4a).
    pub fn upper_bound(&self) -> RtResult<EvalResult> {
        let all_ids = self.pool_ids.clone();
        let pool_labels = self.oracle.eval_labels(&all_ids); // bound, not charged
        let emb = self.init_emb.vstack(&self.pool_emb);
        let mut labels = self.init_labels.clone();
        labels.extend_from_slice(&pool_labels);
        let (head, _) =
            trainer::fit(self.backend.as_ref(), &emb, &labels, self.num_classes, &self.train_cfg)?;
        trainer::evaluate(self.backend.as_ref(), &head, &self.test_emb, &self.test_labels)
    }

    fn arm_mut(&mut self, strategy: &str) -> &mut Arm {
        if !self.arms.contains_key(strategy) {
            // New arms start from the pre-trained baseline head so their
            // first selection is informed (Algorithm 1 line 5).
            let head = self
                .baseline()
                .map(|(h, _)| h)
                .unwrap_or_else(|_| LinearHead::zeros(self.pool_emb.cols(), self.num_classes));
            self.arms.insert(
                strategy.to_string(),
                Arm { labeled: vec![], head, accuracy: vec![] },
            );
        }
        self.arms.get_mut(strategy).unwrap()
    }

    /// Accuracy history of an arm.
    pub fn history(&self, strategy: &str) -> Option<&[f64]> {
        self.arms.get(strategy).map(|a| a.accuracy.as_slice())
    }

    /// Labeled-set size of an arm.
    pub fn labeled_count(&self, strategy: &str) -> usize {
        self.arms.get(strategy).map(|a| a.labeled.len()).unwrap_or(0)
    }

    /// One AL round for `strategy` (the core of the engine). Returns the
    /// post-round test accuracy, or None if fewer than `budget` unlabeled
    /// pool samples remain for this arm.
    pub fn round(&mut self, strategy: &str, budget: usize) -> RtResult<Option<EvalResult>> {
        let strat = strategies::by_name(strategy)
            .unwrap_or_else(|| panic!("unknown strategy '{strategy}'"));
        // Gather this arm's available pool (indices not yet labeled).
        let pool_rows = self.pool_emb.rows();
        let (avail, head, n_prev_rounds) = {
            let arm = self.arm_mut(strategy);
            let labeled: std::collections::HashSet<usize> =
                arm.labeled.iter().copied().collect();
            let avail: Vec<usize> =
                (0..pool_rows).filter(|i| !labeled.contains(i)).collect();
            (avail, arm.head.clone(), arm.accuracy.len() as u64)
        };
        if avail.len() < budget {
            return Ok(None);
        }
        let avail_emb = self.pool_emb.gather_rows(&avail);
        // uncertainty statistics under the arm's current head
        let logits = self.backend.eval_logits(&avail_emb, &head.w, &head.b)?;
        let scores = self.backend.scores(&logits)?;
        // labeled context = init + arm's labeled pool samples
        let labeled_emb = {
            let arm = self.arms.get(strategy).unwrap();
            if arm.labeled.is_empty() {
                self.init_emb.clone()
            } else {
                self.init_emb.vstack(&self.pool_emb.gather_rows(&arm.labeled))
            }
        };
        let ctx = SelectCtx {
            scores: &scores,
            embeddings: &avail_emb,
            labeled: &labeled_emb,
            backend: self.backend.as_ref(),
            // shared with the served agent job (remote parity contract)
            seed: crate::agent::arm_round_seed(self.seed, n_prev_rounds),
        };
        let picked_rel = strat.select(&ctx, budget)?;
        let picked_abs: Vec<usize> = picked_rel.iter().map(|&r| avail[r]).collect();

        // oracle labels the selection (budget accounting)
        let ids: Vec<u32> = picked_abs.iter().map(|&i| self.pool_ids[i]).collect();
        let _new_labels = self.oracle.label(&ids);

        // retrain from scratch on init + all labeled (paper fine-tunes the
        // last layer each round)
        let (emb, labels) = {
            let arm = self.arms.get_mut(strategy).unwrap();
            arm.labeled.extend_from_slice(&picked_abs);
            let lab_ids: Vec<u32> = arm.labeled.iter().map(|&i| self.pool_ids[i]).collect();
            let lab_labels = self.oracle.eval_labels(&lab_ids); // already paid above
            let emb = self.init_emb.vstack(&self.pool_emb.gather_rows(&arm.labeled));
            let mut labels = self.init_labels.clone();
            labels.extend_from_slice(&lab_labels);
            (emb, labels)
        };
        let (new_head, _) =
            trainer::fit(self.backend.as_ref(), &emb, &labels, self.num_classes, &self.train_cfg)?;
        let acc = trainer::evaluate(
            self.backend.as_ref(),
            &new_head,
            &self.test_emb,
            &self.test_labels,
        )?;
        let arm = self.arms.get_mut(strategy).unwrap();
        arm.head = new_head;
        arm.accuracy.push(acc.top1);
        Ok(Some(acc))
    }

    /// One-round AL (the Table 2 / Fig 4a protocol): fresh arm, single
    /// selection of `budget`, returns (top1, top5).
    pub fn one_round(&mut self, strategy: &str, budget: usize) -> RtResult<EvalResult> {
        self.arms.remove(strategy);
        // a fresh arm starts from the baseline head (see arm_mut), so the
        // selection is informed — the paper trains the initial model on
        // the seed set before the one-round scan
        self.round(strategy, budget)?
            .ok_or_else(|| {
                crate::runtime::backend::RuntimeError::Shape(format!(
                    "pool too small for one-round budget {budget}"
                ))
            })
    }
}

impl AlTask for AlExperiment {
    fn run_round(&mut self, strategy: &str, budget: usize) -> RtResult<Option<f64>> {
        Ok(self.round(strategy, budget)?.map(|r| r.top1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::HostBackend;
    use crate::util::rng::Rng;

    /// Toy experiment: separable embedding clusters, no image pipeline.
    fn toy_experiment(seed: u64) -> AlExperiment {
        let backend: Arc<dyn ComputeBackend> = Arc::new(HostBackend::new());
        let mut rng = Rng::new(seed);
        let c = 5;
        let d = 8;
        let gen_split = |rng: &mut Rng, n: usize| -> (Mat, Vec<u8>) {
            let mut m = Mat::zeros(n, d);
            let mut labels = Vec::with_capacity(n);
            for i in 0..n {
                let class = rng.below(c);
                labels.push(class as u8);
                let row = m.row_mut(i);
                for j in 0..d {
                    row[j] = 0.5 * rng.normal_f32();
                }
                row[class] += 2.0;
            }
            (m, labels)
        };
        let (init_emb, init_labels) = gen_split(&mut rng, 20);
        let (pool_emb, pool_labels) = gen_split(&mut rng, 200);
        let (test_emb, test_labels) = gen_split(&mut rng, 150);
        // oracle over pool ids 0..200
        let oracle = Arc::new(Oracle::from_labels(pool_labels));
        let pool_ids: Vec<u32> = (0..200).collect();
        AlExperiment::from_embeddings(
            backend,
            pool_emb,
            pool_ids,
            init_emb,
            init_labels,
            test_emb,
            test_labels,
            oracle,
            c,
            TrainConfig { epochs: 15, ..Default::default() },
            seed,
        )
    }

    #[test]
    fn accuracy_improves_over_rounds() {
        let mut exp = toy_experiment(1);
        let (_, base) = exp.baseline().unwrap();
        let mut accs = vec![base.top1];
        for _ in 0..4 {
            let r = exp.round("least_confidence", 30).unwrap().unwrap();
            accs.push(r.top1);
        }
        assert!(
            accs.last().unwrap() > accs.first().unwrap(),
            "AL should improve accuracy: {accs:?}"
        );
    }

    #[test]
    fn arms_are_independent() {
        let mut exp = toy_experiment(2);
        exp.round("least_confidence", 40).unwrap().unwrap();
        exp.round("entropy", 40).unwrap().unwrap();
        assert_eq!(exp.labeled_count("least_confidence"), 40);
        assert_eq!(exp.labeled_count("entropy"), 40);
        // total oracle charges = both arms
        assert_eq!(exp.oracle().budget_spent(), 80);
    }

    #[test]
    fn pool_exhaustion_returns_none() {
        let mut exp = toy_experiment(3);
        assert!(exp.round("random", 150).unwrap().is_some());
        assert!(exp.round("random", 150).unwrap().is_none(), "only 50 left");
    }

    #[test]
    fn upper_bound_beats_baseline() {
        let exp = toy_experiment(4);
        let (_, base) = exp.baseline().unwrap();
        let ub = exp.upper_bound().unwrap();
        assert!(
            ub.top1 >= base.top1,
            "full data {} should be >= init-only {}",
            ub.top1,
            base.top1
        );
    }

    #[test]
    fn one_round_protocol_resets_arm() {
        let mut exp = toy_experiment(5);
        let a = exp.one_round("least_confidence", 50).unwrap();
        let b = exp.one_round("least_confidence", 50).unwrap();
        assert_eq!(exp.labeled_count("least_confidence"), 50, "fresh arm each time");
        assert!((a.top1 - b.top1).abs() < 1e-9, "one_round deterministic");
    }

    #[test]
    fn pshea_runs_on_real_experiment() {
        let mut exp = toy_experiment(6);
        let strategies: Vec<String> = vec![
            "least_confidence".into(),
            "random".into(),
            "entropy".into(),
        ];
        let cfg = crate::agent::PsheaConfig {
            target_accuracy: 1.1, // unreachable -> runs to round limit
            max_budget: 100_000,
            round_budget: 20,
            converge_rounds: 0,
            converge_eps: 0.0,
            max_rounds: 4,
            min_history: 2,
            initial_accuracy: None,
        };
        let trace = crate::agent::run_pshea(&mut exp, &strategies, &cfg).unwrap();
        assert_eq!(trace.rounds, 4);
        assert_eq!(trace.round(0).count(), 3);
        assert_eq!(trace.survivors.len(), 1);
        assert!(trace.best_accuracy > 0.5, "learned something");
    }
}

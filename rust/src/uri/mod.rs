//! Sample URIs. The AL client pushes datasets *by reference* (Figure 1):
//! each sample is a URI the server resolves against an object store —
//! `s3sim://bucket/key` (simulated S3), `file:///abs/path` (local disk),
//! `mem://bucket/key` (in-process store for tests).

use std::fmt;

/// Supported URI schemes (maps 1:1 to `store::` backends).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    File,
    S3Sim,
    Mem,
}

impl Scheme {
    pub fn as_str(self) -> &'static str {
        match self {
            Scheme::File => "file",
            Scheme::S3Sim => "s3sim",
            Scheme::Mem => "mem",
        }
    }
}

/// A parsed sample URI.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Uri {
    pub scheme: Scheme,
    /// Bucket (s3sim/mem) or empty (file).
    pub bucket: String,
    /// Object key (s3sim/mem) or absolute path (file).
    pub key: String,
}

/// URI parse failure.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("invalid uri '{uri}': {reason}")]
pub struct UriError {
    pub uri: String,
    pub reason: String,
}

impl Uri {
    /// Parse `scheme://...`.
    pub fn parse(s: &str) -> Result<Uri, UriError> {
        let err = |reason: &str| UriError { uri: s.to_string(), reason: reason.to_string() };
        let (scheme_str, rest) = s.split_once("://").ok_or_else(|| err("missing '://'"))?;
        match scheme_str {
            "file" => {
                // file:///abs/path -> rest = "/abs/path"
                if !rest.starts_with('/') {
                    return Err(err("file uri must be absolute (file:///path)"));
                }
                Ok(Uri { scheme: Scheme::File, bucket: String::new(), key: rest.to_string() })
            }
            "s3sim" | "mem" => {
                let scheme = if scheme_str == "s3sim" { Scheme::S3Sim } else { Scheme::Mem };
                let (bucket, key) =
                    rest.split_once('/').ok_or_else(|| err("expected bucket/key"))?;
                if bucket.is_empty() {
                    return Err(err("empty bucket"));
                }
                if key.is_empty() {
                    return Err(err("empty key"));
                }
                if !bucket.chars().all(|c| c.is_ascii_alphanumeric() || "-._".contains(c)) {
                    return Err(err("bucket has invalid characters"));
                }
                Ok(Uri { scheme, bucket: bucket.to_string(), key: key.to_string() })
            }
            other => Err(err(&format!("unknown scheme '{other}'"))),
        }
    }

    /// Canonical string form (parse . to_string = id).
    pub fn to_uri_string(&self) -> String {
        match self.scheme {
            Scheme::File => format!("file://{}", self.key),
            _ => format!("{}://{}/{}", self.scheme.as_str(), self.bucket, self.key),
        }
    }
}

impl fmt::Display for Uri {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_uri_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_schemes() {
        let u = Uri::parse("s3sim://cifar/pool/img_000001.bin").unwrap();
        assert_eq!(u.scheme, Scheme::S3Sim);
        assert_eq!(u.bucket, "cifar");
        assert_eq!(u.key, "pool/img_000001.bin");

        let u = Uri::parse("file:///data/x.bin").unwrap();
        assert_eq!(u.scheme, Scheme::File);
        assert_eq!(u.key, "/data/x.bin");

        let u = Uri::parse("mem://t/a").unwrap();
        assert_eq!(u.scheme, Scheme::Mem);
    }

    #[test]
    fn roundtrip() {
        for s in ["s3sim://b/k/deep/key.bin", "file:///a/b.bin", "mem://x/y"] {
            assert_eq!(Uri::parse(s).unwrap().to_uri_string(), s);
        }
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "nope", "http://a/b", "s3sim://", "s3sim://bucket", "s3sim:///key",
            "s3sim://bucket/", "file://relative/path", "s3sim://bad bucket/k",
        ] {
            assert!(Uri::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn prop_roundtrip_random_keys() {
        crate::util::prop::check("uri-roundtrip", 100, |rng| {
            let bucket: String =
                (0..1 + rng.below(10)).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
            let key: String = (0..1 + rng.below(30))
                .map(|_| {
                    let chars = b"abcdefghij0123456789/._-";
                    chars[rng.below(chars.len())] as char
                })
                .collect();
            let s = format!("s3sim://{bucket}/{key}");
            match Uri::parse(&s) {
                Ok(u) => crate::prop_assert!(
                    u.to_uri_string() == s,
                    "roundtrip mismatch: {s} -> {}",
                    u.to_uri_string()
                ),
                Err(_) => {} // some random keys are legitimately invalid (e.g. empty)
            }
            Ok(())
        });
    }
}

//! Random sampling — the paper's lower-bound baseline in Fig 4a.

use super::{SelectCtx, Strategy};
use crate::runtime::backend::RtResult;
use crate::util::rng::Rng;

/// Uniform sampling without replacement.
pub struct Random;

impl Strategy for Random {
    fn name(&self) -> &'static str {
        "random"
    }

    fn select(&self, ctx: &SelectCtx<'_>, budget: usize) -> RtResult<Vec<usize>> {
        let n = ctx.scores.rows();
        let mut rng = Rng::new(ctx.seed);
        Ok(rng.sample_indices(n, budget.min(n)))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{assert_valid_selection, Fixture};
    use super::*;

    #[test]
    fn seed_controls_selection() {
        let fx = Fixture::new(100, 8, 1);
        let mut ctx = fx.ctx();
        let a = Random.select(&ctx, 30).unwrap();
        ctx.seed = 100;
        let b = Random.select(&ctx, 30).unwrap();
        assert_ne!(a, b, "different seeds should differ");
        assert_valid_selection(&a, 100, 30);
        assert_valid_selection(&b, 100, 30);
    }

    #[test]
    fn covers_pool_roughly_uniformly() {
        let fx = Fixture::new(50, 4, 2);
        let mut counts = vec![0u32; 50];
        for seed in 0..200 {
            let mut ctx = fx.ctx();
            ctx.seed = seed;
            for i in Random.select(&ctx, 10).unwrap() {
                counts[i] += 1;
            }
        }
        // each index expected 40 times; allow generous spread
        assert!(counts.iter().all(|&c| c > 10 && c < 90), "{counts:?}");
    }
}

//! Uncertainty sampling family: LC [Lewis & Gale '94], MC [Scheffer '01],
//! RC / ES [Settles '09]. All four consume columns of the fused score
//! matrix the L1 Pallas kernel produced — selection itself is a top-k.

use super::{ScoreColumn, SelectCtx, Strategy};
use crate::runtime::backend::RtResult;
use crate::util::topk;

fn column(ctx: &SelectCtx<'_>, col: ScoreColumn) -> Vec<f32> {
    let scores = ctx.scores;
    (0..scores.rows()).map(|i| scores.get(i, col as usize)).collect()
}

/// Least confidence: select the samples with the *highest* `1 - p_max`.
pub struct LeastConfidence;

impl Strategy for LeastConfidence {
    fn name(&self) -> &'static str {
        "least_confidence"
    }

    fn select(&self, ctx: &SelectCtx<'_>, budget: usize) -> RtResult<Vec<usize>> {
        Ok(topk::top_k_desc(&column(ctx, ScoreColumn::LeastConfidence), budget))
    }
}

/// Margin confidence: select the samples with the *lowest* `p1 - p2`.
pub struct MarginConfidence;

impl Strategy for MarginConfidence {
    fn name(&self) -> &'static str {
        "margin_confidence"
    }

    fn select(&self, ctx: &SelectCtx<'_>, budget: usize) -> RtResult<Vec<usize>> {
        Ok(topk::top_k_asc(&column(ctx, ScoreColumn::Margin), budget))
    }
}

/// Ratio confidence: select the samples with the *highest* `p2 / p1`.
pub struct RatioConfidence;

impl Strategy for RatioConfidence {
    fn name(&self) -> &'static str {
        "ratio_confidence"
    }

    fn select(&self, ctx: &SelectCtx<'_>, budget: usize) -> RtResult<Vec<usize>> {
        Ok(topk::top_k_desc(&column(ctx, ScoreColumn::Ratio), budget))
    }
}

/// Entropy sampling: select the samples with the *highest* entropy.
pub struct Entropy;

impl Strategy for Entropy {
    fn name(&self) -> &'static str {
        "entropy"
    }

    fn select(&self, ctx: &SelectCtx<'_>, budget: usize) -> RtResult<Vec<usize>> {
        Ok(topk::top_k_desc(&column(ctx, ScoreColumn::Entropy), budget))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Fixture;
    use super::super::Strategy;
    use super::*;
    use crate::runtime::backend::{host_scores, HostBackend};
    use crate::util::mat::Mat;

    /// Construct logits with known uncertainty ordering and verify each
    /// strategy picks the intended samples.
    #[test]
    fn selects_most_uncertain_by_construction() {
        // sample 0: uniform (max uncertainty), sample 1: mildly peaked,
        // sample 2: extremely peaked (min uncertainty).
        let mut logits = Mat::zeros(3, 10);
        logits.set(1, 0, 2.0);
        logits.set(2, 0, 50.0);
        let scores = host_scores(&logits);
        let emb = Mat::zeros(3, 4);
        let labeled = Mat::zeros(0, 4);
        let backend = HostBackend::new();
        let ctx = SelectCtx {
            scores: &scores,
            embeddings: &emb,
            labeled: &labeled,
            backend: &backend,
            seed: 0,
        };
        for s in [
            &LeastConfidence as &dyn Strategy,
            &MarginConfidence,
            &RatioConfidence,
            &Entropy,
        ] {
            let sel = s.select(&ctx, 2).unwrap();
            assert_eq!(sel, vec![0, 1], "{} ordering", s.name());
        }
    }

    #[test]
    fn lc_and_margin_agree_on_fixture_ordering() {
        // In the fixture, margin = 1 - lc, so LC-desc == MC-asc.
        let fx = Fixture::new(60, 8, 3);
        let lc = LeastConfidence.select(&fx.ctx(), 10).unwrap();
        let mc = MarginConfidence.select(&fx.ctx(), 10).unwrap();
        assert_eq!(lc, mc);
    }
}

//! K-Center Greedy [Sener & Savarese '18's greedy core, also Nguyen &
//! Smeulders '04 pre-clustering lineage]: iteratively pick the pool point
//! farthest from the current center set.
//!
//! Implementation: the *bulk* pool-vs-labeled distance block goes through
//! the backend (the tiled MXU Pallas kernel); the per-iteration update
//! after adding one center is a rank-1 min-dist refresh done on the host
//! (one dot product per pool point — far cheaper than a padded 256x256
//! kernel tile for a single center; see DESIGN.md §Perf).

use super::{SelectCtx, Strategy};
use crate::runtime::backend::RtResult;
use crate::util::mat::Mat;

/// Greedy k-center selection.
#[derive(Default)]
pub struct KCenterGreedy;

/// Squared distance between two rows (host hot loop).
#[inline]
pub(crate) fn row_sqdist(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

/// Initial min-distance of every pool point to the labeled set (bulk block
/// via the backend kernel; +inf when nothing is labeled yet).
pub(crate) fn initial_min_dists(ctx: &SelectCtx<'_>) -> RtResult<Vec<f32>> {
    let n = ctx.embeddings.rows();
    if ctx.labeled.rows() == 0 {
        return Ok(vec![f32::INFINITY; n]);
    }
    let d = ctx.backend.sqdist(ctx.embeddings, ctx.labeled)?;
    Ok((0..n)
        .map(|i| d.row(i).iter().cloned().fold(f32::INFINITY, f32::min))
        .collect())
}

/// Run the greedy loop starting from `min_dists`, returning selected pool
/// indices. Shared by KCG and Core-Set.
pub(crate) fn greedy_k_center(
    embeddings: &Mat,
    mut min_dists: Vec<f32>,
    budget: usize,
) -> Vec<usize> {
    let n = embeddings.rows();
    let budget = budget.min(n);
    let mut selected = Vec::with_capacity(budget);
    let mut taken = vec![false; n];
    for _ in 0..budget {
        // farthest point from all centers so far (ties -> lowest index)
        let mut best = None;
        let mut best_d = f32::NEG_INFINITY;
        for i in 0..n {
            if !taken[i] && min_dists[i] > best_d {
                best_d = min_dists[i];
                best = Some(i);
            }
        }
        let Some(c) = best else { break };
        taken[c] = true;
        selected.push(c);
        // rank-1 min-dist refresh against the new center
        let center = embeddings.row(c).to_vec();
        for i in 0..n {
            if !taken[i] {
                let d = row_sqdist(embeddings.row(i), &center);
                if d < min_dists[i] {
                    min_dists[i] = d;
                }
            }
        }
    }
    selected
}

impl Strategy for KCenterGreedy {
    fn name(&self) -> &'static str {
        "k_center_greedy"
    }

    fn select(&self, ctx: &SelectCtx<'_>, budget: usize) -> RtResult<Vec<usize>> {
        let min_dists = initial_min_dists(ctx)?;
        Ok(greedy_k_center(ctx.embeddings, min_dists, budget))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{assert_valid_selection, Fixture};
    use super::super::SelectCtx;
    use super::*;
    use crate::runtime::backend::HostBackend;

    #[test]
    fn covers_all_clusters_before_revisiting() {
        // 5 tight clusters; with budget 5 and no labeled set, greedy
        // k-center must pick one point from each cluster.
        let fx = Fixture::new(100, 8, 11);
        let labeled = Mat::zeros(0, 8);
        let ctx = SelectCtx { labeled: &labeled, ..fx.ctx() };
        let sel = KCenterGreedy.select(&ctx, 5).unwrap();
        assert_valid_selection(&sel, 100, 5);
        let clusters: std::collections::HashSet<usize> = sel.iter().map(|i| i % 5).collect();
        assert_eq!(clusters.len(), 5, "one pick per cluster: {sel:?}");
    }

    #[test]
    fn avoids_clusters_already_labeled() {
        // Labeled set sits on clusters 0..3 (fixture); with budget 2 the
        // first two picks must come from clusters 3 and 4.
        let fx = Fixture::new(100, 8, 12);
        let sel = KCenterGreedy.select(&fx.ctx(), 2).unwrap();
        let clusters: std::collections::HashSet<usize> = sel.iter().map(|i| i % 5).collect();
        assert_eq!(
            clusters,
            [3usize, 4].into_iter().collect(),
            "should target uncovered clusters, got {sel:?}"
        );
    }

    #[test]
    fn first_pick_is_farthest_point() {
        let backend = HostBackend::new();
        let mut emb = Mat::zeros(4, 2);
        emb.set(1, 0, 1.0);
        emb.set(2, 0, 5.0);
        emb.set(3, 0, 2.0);
        let labeled = Mat::from_vec(vec![0.0, 0.0], 1, 2);
        let scores = Mat::zeros(4, 4);
        let ctx = SelectCtx {
            scores: &scores,
            embeddings: &emb,
            labeled: &labeled,
            backend: &backend,
            seed: 0,
        };
        let sel = KCenterGreedy.select(&ctx, 2).unwrap();
        assert_eq!(sel[0], 2, "farthest from origin first");
        // next farthest from {origin, x=5} is x=2 (min-dist 4 vs x=1's 1)
        assert_eq!(sel[1], 3);
    }

    #[test]
    fn budget_exceeding_pool_selects_everything() {
        let fx = Fixture::new(10, 4, 13);
        let sel = KCenterGreedy.select(&fx.ctx(), 50).unwrap();
        assert_valid_selection(&sel, 10, 50);
        assert_eq!(sel.len(), 10);
    }
}

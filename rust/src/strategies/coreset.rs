//! Core-Set [Sener & Savarese, ICLR '18]: minimax-facility selection.
//!
//! The paper's strongest (and slowest — Fig 4b) strategy. The full method
//! is the greedy 2-approximation plus a robust improvement step (the
//! authors' MIP with outlier slack). We reproduce that structure as
//! greedy init + bounded local-search swap passes minimizing the robust
//! cover radius (max min-dist excluding an outlier fraction), which keeps
//! the "heavy design" cost profile the paper reports: strictly more
//! compute than KCG for a measurably tighter cover (see the
//! `improves_cover_radius_over_greedy` test and the fig4b bench).

use super::kcenter::{greedy_k_center, initial_min_dists, row_sqdist};
use super::{SelectCtx, Strategy};
use crate::runtime::backend::RtResult;
use crate::util::rng::Rng;

/// Robust k-center with local-search refinement.
pub struct CoreSet {
    /// Local-search passes over the center set.
    pub improve_passes: usize,
    /// Fraction of farthest points treated as outliers when scoring a
    /// cover (the robustness slack of the original formulation).
    pub outlier_frac: f64,
}

impl Default for CoreSet {
    fn default() -> Self {
        CoreSet { improve_passes: 2, outlier_frac: 0.01 }
    }
}

/// Robust cover radius: max min-dist after dropping the `outlier_frac`
/// farthest points.
fn robust_radius(min_dists: &[f32], outlier_frac: f64) -> f32 {
    let mut d: Vec<f32> = min_dists.to_vec();
    d.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    let keep = ((d.len() as f64) * (1.0 - outlier_frac)).ceil().max(1.0) as usize;
    d[keep.min(d.len()) - 1]
}

/// Min-dist of every pool point to `centers` (pool indices) combined with
/// the baseline labeled-set distances.
fn cover_dists(
    emb: &crate::util::mat::Mat,
    base: &[f32],
    centers: &[usize],
) -> Vec<f32> {
    let n = emb.rows();
    let mut md = base.to_vec();
    for &c in centers {
        let row = emb.row(c).to_vec();
        for i in 0..n {
            let d = row_sqdist(emb.row(i), &row);
            if d < md[i] {
                md[i] = d;
            }
        }
    }
    md
}

impl Strategy for CoreSet {
    fn name(&self) -> &'static str {
        "core_set"
    }

    fn select(&self, ctx: &SelectCtx<'_>, budget: usize) -> RtResult<Vec<usize>> {
        let emb = ctx.embeddings;
        let n = emb.rows();
        let budget = budget.min(n);
        if budget == 0 {
            return Ok(vec![]);
        }
        let base = initial_min_dists(ctx)?;
        let mut centers = greedy_k_center(emb, base.clone(), budget);
        if centers.len() < budget {
            return Ok(centers); // pool exhausted
        }

        let mut rng = Rng::new(ctx.seed ^ 0xC0DE_5E7);
        let mut best_md = cover_dists(emb, &base, &centers);
        let mut best_r = robust_radius(&best_md, self.outlier_frac);

        // Local search: try swapping each center for the current worst
        // (farthest uncovered, non-outlier) point; keep improving swaps.
        for _pass in 0..self.improve_passes {
            let mut improved = false;
            // candidate replacement: the robust-worst point not already a center
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_unstable_by(|&a, &b| {
                best_md[b].partial_cmp(&best_md[a]).unwrap()
            });
            let n_out = ((n as f64) * self.outlier_frac).floor() as usize;
            let candidate = order
                .into_iter()
                .skip(n_out)
                .find(|i| !centers.contains(i));
            let Some(cand) = candidate else { break };

            // try replacing a few random centers with the candidate
            let tries = centers.len().min(8);
            for _ in 0..tries {
                let slot = rng.below(centers.len());
                let old = centers[slot];
                centers[slot] = cand;
                let md = cover_dists(emb, &base, &centers);
                let r = robust_radius(&md, self.outlier_frac);
                if r + 1e-9 < best_r {
                    best_r = r;
                    best_md = md;
                    improved = true;
                    break;
                }
                centers[slot] = old;
            }
            if !improved {
                break;
            }
        }
        Ok(centers)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{assert_valid_selection, Fixture};
    use super::super::SelectCtx;
    use super::*;
    use crate::util::mat::Mat;

    #[test]
    fn selection_invariants_hold_after_refinement() {
        let fx = Fixture::new(150, 8, 21);
        let sel = CoreSet::default().select(&fx.ctx(), 12).unwrap();
        assert_valid_selection(&sel, 150, 12);
    }

    #[test]
    fn improves_cover_radius_over_greedy() {
        // Across fixtures, refinement must never be worse than greedy and
        // should win at least sometimes.
        let mut wins = 0;
        let mut total = 0;
        for seed in 0..10u64 {
            let fx = Fixture::new(120, 8, seed);
            let labeled = Mat::zeros(0, 8);
            let ctx = SelectCtx { labeled: &labeled, ..fx.ctx() };
            let greedy =
                super::super::KCenterGreedy.select(&ctx, 8).unwrap();
            let refined = CoreSet { improve_passes: 6, outlier_frac: 0.02 }
                .select(&ctx, 8)
                .unwrap();
            let base = vec![f32::INFINITY; 120];
            let rg = robust_radius(&cover_dists(&fx.embeddings, &base, &greedy), 0.02);
            let rr = robust_radius(&cover_dists(&fx.embeddings, &base, &refined), 0.02);
            assert!(rr <= rg + 1e-6, "seed {seed}: refined {rr} worse than greedy {rg}");
            if rr < rg - 1e-6 {
                wins += 1;
            }
            total += 1;
        }
        assert!(wins > 0, "refinement never improved over greedy in {total} trials");
    }

    #[test]
    fn robust_radius_ignores_outliers() {
        let dists = vec![1.0, 1.0, 1.0, 100.0];
        assert_eq!(robust_radius(&dists, 0.25), 1.0);
        assert_eq!(robust_radius(&dists, 0.0), 100.0);
    }

    #[test]
    fn zero_outlier_frac_is_plain_radius() {
        let dists = vec![0.5, 2.0, 1.5];
        assert_eq!(robust_radius(&dists, 0.0), 2.0);
    }
}

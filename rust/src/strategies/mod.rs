//! The AL Strategy Zoo (paper §3.1, Table 1; evaluated in Fig 4a/4b).
//!
//! Exactly the strategies the paper benchmarks:
//!
//! | name               | paper label | class        |
//! |--------------------|-------------|--------------|
//! | `random`           | Random      | lower bound  |
//! | `least_confidence` | LC          | uncertainty  |
//! | `margin_confidence`| MC          | uncertainty  |
//! | `ratio_confidence` | RC          | uncertainty  |
//! | `entropy`          | ES          | uncertainty  |
//! | `k_center_greedy`  | KCG         | diversity    |
//! | `core_set`         | Core-Set    | diversity    |
//! | `dbal`             | DBAL        | hybrid       |
//!
//! A strategy maps pool statistics (uncertainty scores from the fused L1
//! kernel, embeddings from the trunk) to the `budget` indices most worth
//! labeling. Invariants enforced by tests on every strategy: selection is
//! a subset of the pool, has exactly `min(budget, pool)` distinct indices,
//! and is deterministic given (inputs, seed).

mod coreset;
mod dbal;
mod kcenter;
mod random;
mod uncertainty;

pub use coreset::CoreSet;
pub use dbal::Dbal;
pub use kcenter::KCenterGreedy;
pub use random::Random;
pub use uncertainty::{Entropy, LeastConfidence, MarginConfidence, RatioConfidence};

use crate::runtime::backend::{ComputeBackend, RtResult};
use crate::util::mat::Mat;

/// Column layout of the `[N, 4]` score matrix produced by the fused
/// uncertainty kernel. Keep in sync with python/compile/kernels/ref.py.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoreColumn {
    LeastConfidence = 0,
    Margin = 1,
    Ratio = 2,
    Entropy = 3,
}

/// Everything a strategy may look at when selecting.
pub struct SelectCtx<'a> {
    /// `[N, 4]` uncertainty scores of the candidate pool.
    pub scores: &'a Mat,
    /// `[N, D]` embeddings of the candidate pool.
    pub embeddings: &'a Mat,
    /// `[L, D]` embeddings of already-labeled samples (diversity methods
    /// avoid re-covering them). Empty matrix = nothing labeled yet.
    pub labeled: &'a Mat,
    /// Compute backend for bulk math (tiled distance blocks).
    pub backend: &'a dyn ComputeBackend,
    /// Seed for any internal randomness (k-means init, tie-breaks).
    pub seed: u64,
}

/// A pool-based AL strategy.
pub trait Strategy: Send + Sync {
    /// Zoo name (stable; used in configs and RPC).
    fn name(&self) -> &'static str;
    /// Indices (into the pool) of the `budget` samples to label.
    fn select(&self, ctx: &SelectCtx<'_>, budget: usize) -> RtResult<Vec<usize>>;
}

/// All zoo strategies in paper order (Fig 4's x-axis).
pub fn zoo() -> Vec<Box<dyn Strategy>> {
    vec![
        Box::new(Random),
        Box::new(LeastConfidence),
        Box::new(MarginConfidence),
        Box::new(RatioConfidence),
        Box::new(Entropy),
        Box::new(KCenterGreedy::default()),
        Box::new(CoreSet::default()),
        Box::new(Dbal::default()),
    ]
}

/// Names of every zoo strategy.
pub fn zoo_names() -> Vec<&'static str> {
    zoo().iter().map(|s| s.name()).collect()
}

/// The 7 non-random candidates PSHEA launches (paper §4.3.3).
pub fn candidate_names() -> Vec<&'static str> {
    zoo_names().into_iter().filter(|n| *n != "random").collect()
}

/// Look up a strategy by zoo name.
pub fn by_name(name: &str) -> Option<Box<dyn Strategy>> {
    zoo().into_iter().find(|s| s.name() == name)
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::runtime::backend::HostBackend;
    use crate::util::rng::Rng;

    /// Deterministic pool with cluster structure + a labeled set.
    pub struct Fixture {
        pub scores: Mat,
        pub embeddings: Mat,
        pub labeled: Mat,
        pub backend: HostBackend,
    }

    impl Fixture {
        pub fn new(n: usize, d: usize, seed: u64) -> Self {
            let mut rng = Rng::new(seed);
            // 5 well-separated cluster centers
            let k = 5;
            let centers: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..d).map(|_| 3.0 * rng.normal_f32()).collect())
                .collect();
            let mut emb = Mat::zeros(n, d);
            for i in 0..n {
                let c = &centers[i % k];
                let row = emb.row_mut(i);
                for j in 0..d {
                    row[j] = c[j] + 0.3 * rng.normal_f32();
                }
            }
            let mut scores = Mat::zeros(n, 4);
            for i in 0..n {
                let u = rng.f32();
                let row = scores.row_mut(i);
                row[0] = u; // lc: higher = more uncertain
                row[1] = 1.0 - u; // margin: lower = more uncertain
                row[2] = u; // ratio
                row[3] = u * (10.0f32).ln(); // entropy
            }
            let mut labeled = Mat::zeros(3, d);
            for i in 0..3 {
                let row = labeled.row_mut(i);
                for j in 0..d {
                    row[j] = centers[i][j];
                }
            }
            Fixture { scores, embeddings: emb, labeled, backend: HostBackend::new() }
        }

        pub fn ctx(&self) -> SelectCtx<'_> {
            SelectCtx {
                scores: &self.scores,
                embeddings: &self.embeddings,
                labeled: &self.labeled,
                backend: &self.backend,
                seed: 99,
            }
        }
    }

    /// The invariants every strategy must uphold.
    pub fn assert_valid_selection(sel: &[usize], pool: usize, budget: usize) {
        assert_eq!(sel.len(), budget.min(pool), "selection size");
        let mut s = sel.to_vec();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), sel.len(), "duplicate selections");
        assert!(sel.iter().all(|&i| i < pool), "index out of pool");
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::{assert_valid_selection, Fixture};
    use super::*;

    #[test]
    fn zoo_contains_paper_strategies() {
        let names = zoo_names();
        for want in [
            "random",
            "least_confidence",
            "margin_confidence",
            "ratio_confidence",
            "entropy",
            "k_center_greedy",
            "core_set",
            "dbal",
        ] {
            assert!(names.contains(&want), "missing {want}");
        }
        assert_eq!(candidate_names().len(), 7, "PSHEA launches 7 candidates");
    }

    #[test]
    fn by_name_roundtrip() {
        for name in zoo_names() {
            assert_eq!(by_name(name).unwrap().name(), name);
        }
        assert!(by_name("nonsense").is_none());
    }

    #[test]
    fn every_strategy_upholds_selection_invariants() {
        let fx = Fixture::new(120, 16, 5);
        for s in zoo() {
            for budget in [1usize, 7, 40, 120, 500] {
                let sel = s.select(&fx.ctx(), budget).unwrap_or_else(|e| {
                    panic!("{} failed at budget {budget}: {e}", s.name())
                });
                assert_valid_selection(&sel, 120, budget);
            }
        }
    }

    #[test]
    fn every_strategy_is_deterministic() {
        let fx = Fixture::new(80, 8, 6);
        for s in zoo() {
            let a = s.select(&fx.ctx(), 20).unwrap();
            let b = s.select(&fx.ctx(), 20).unwrap();
            assert_eq!(a, b, "{} not deterministic", s.name());
        }
    }

    #[test]
    fn zero_budget_selects_nothing() {
        let fx = Fixture::new(30, 8, 7);
        for s in zoo() {
            assert!(s.select(&fx.ctx(), 0).unwrap().is_empty(), "{}", s.name());
        }
    }
}

//! Diverse Mini-Batch AL (DBAL) [Zhdanov '19]: the hybrid strategy.
//!
//! 1. prefilter the pool to the `beta * budget` most informative samples
//!    (margin informativeness, like the original paper);
//! 2. weighted k-means (k = budget) over their embeddings, weights =
//!    informativeness — the backend's tiled distance kernel does the bulk
//!    assignment blocks;
//! 3. return the medoid (closest pool member to each centroid), dropping
//!    duplicate medoids in favor of next-closest members.

use super::{ScoreColumn, SelectCtx, Strategy};
use crate::runtime::backend::RtResult;
use crate::util::mat::Mat;
use crate::util::rng::Rng;
use crate::util::topk;

/// Weighted k-means + medoid extraction over an informative prefilter.
pub struct Dbal {
    /// Prefilter multiplier (candidates = beta * budget).
    pub beta: usize,
    /// Lloyd iterations.
    pub iters: usize,
}

impl Default for Dbal {
    fn default() -> Self {
        Dbal { beta: 10, iters: 8 }
    }
}

impl Strategy for Dbal {
    fn name(&self) -> &'static str {
        "dbal"
    }

    fn select(&self, ctx: &SelectCtx<'_>, budget: usize) -> RtResult<Vec<usize>> {
        let n = ctx.embeddings.rows();
        let budget = budget.min(n);
        if budget == 0 {
            return Ok(vec![]);
        }
        // 1. informativeness = 1 - margin (higher = more uncertain)
        let margin: Vec<f32> =
            (0..n).map(|i| ctx.scores.get(i, ScoreColumn::Margin as usize)).collect();
        let info: Vec<f32> = margin.iter().map(|m| 1.0 - m).collect();
        let cand = topk::top_k_desc(&info, (self.beta * budget).min(n));
        if cand.len() <= budget {
            return Ok(cand);
        }
        let cemb = ctx.embeddings.gather_rows(&cand);
        let weights: Vec<f32> = cand.iter().map(|&i| info[i].max(1e-3)).collect();

        // 2. weighted k-means: k-means++-ish seeded init, Lloyd iterations
        // with the bulk [candidates x centroids] distance blocks on the
        // backend kernel.
        let k = budget;
        let mut rng = Rng::new(ctx.seed ^ 0xD8A1);
        let mut centroids = init_centroids(&cemb, k, &mut rng);
        let mut assign = vec![0usize; cand.len()];
        for _ in 0..self.iters {
            let d = ctx.backend.sqdist(&cemb, &centroids)?;
            let mut changed = false;
            for i in 0..cand.len() {
                let row = d.row(i);
                let mut best = 0;
                let mut bd = f32::INFINITY;
                for (j, &v) in row.iter().enumerate() {
                    if v < bd {
                        bd = v;
                        best = j;
                    }
                }
                if assign[i] != best {
                    assign[i] = best;
                    changed = true;
                }
            }
            // weighted centroid update
            let dim = cemb.cols();
            let mut sums = Mat::zeros(k, dim);
            let mut wsum = vec![0.0f32; k];
            for i in 0..cand.len() {
                let a = assign[i];
                wsum[a] += weights[i];
                let row = cemb.row(i);
                let srow = sums.row_mut(a);
                for (s, v) in srow.iter_mut().zip(row) {
                    *s += weights[i] * v;
                }
            }
            for j in 0..k {
                if wsum[j] > 0.0 {
                    let srow = sums.row_mut(j);
                    for s in srow.iter_mut() {
                        *s /= wsum[j];
                    }
                } else {
                    // dead centroid: re-seed on a random candidate
                    let pick = rng.below(cand.len());
                    let row = cemb.row(pick).to_vec();
                    sums.row_mut(j).copy_from_slice(&row);
                }
            }
            centroids = sums;
            if !changed {
                break;
            }
        }

        // 3. medoids: per centroid, the nearest unused candidate.
        let d = ctx.backend.sqdist(&centroids, &cemb)?;
        let mut used = vec![false; cand.len()];
        let mut out = Vec::with_capacity(k);
        for j in 0..k {
            let row = d.row(j);
            let mut order: Vec<usize> = (0..cand.len()).collect();
            order.sort_unstable_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap());
            if let Some(&pick) = order.iter().find(|&&i| !used[i]) {
                used[pick] = true;
                out.push(cand[pick]);
            }
        }
        // duplicates removed above may leave a shortfall if k > candidates
        debug_assert_eq!(out.len(), k.min(cand.len()));
        Ok(out)
    }
}

/// k-means++ style init: first uniform, then distance-weighted.
fn init_centroids(emb: &Mat, k: usize, rng: &mut Rng) -> Mat {
    let n = emb.rows();
    let mut chosen = vec![rng.below(n)];
    let mut min_d = vec![f32::INFINITY; n];
    while chosen.len() < k {
        let last = emb.row(*chosen.last().unwrap()).to_vec();
        let mut total = 0.0f64;
        for i in 0..n {
            let d = super::kcenter::row_sqdist(emb.row(i), &last);
            if d < min_d[i] {
                min_d[i] = d;
            }
            total += min_d[i] as f64;
        }
        if total <= 0.0 {
            // all points identical: fill with round-robin
            chosen.push(chosen.len() % n);
            continue;
        }
        let mut u = rng.f64() * total;
        let mut pick = n - 1;
        for (i, &d) in min_d.iter().enumerate() {
            u -= d as f64;
            if u <= 0.0 {
                pick = i;
                break;
            }
        }
        chosen.push(pick);
    }
    emb.gather_rows(&chosen)
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{assert_valid_selection, Fixture};
    use super::super::Strategy;
    use super::*;

    #[test]
    fn invariants_and_determinism() {
        let fx = Fixture::new(200, 8, 31);
        let s = Dbal::default();
        let a = s.select(&fx.ctx(), 15).unwrap();
        assert_valid_selection(&a, 200, 15);
        let b = s.select(&fx.ctx(), 15).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn prefilter_respects_informativeness() {
        // With beta=1 the selection IS the top-budget by informativeness.
        let fx = Fixture::new(100, 8, 32);
        let s = Dbal { beta: 1, iters: 4 };
        let sel = s.select(&fx.ctx(), 10).unwrap();
        let margin: Vec<f32> = (0..100).map(|i| fx.scores.get(i, 1)).collect();
        let info: Vec<f32> = margin.iter().map(|m| 1.0 - m).collect();
        let want = crate::util::topk::top_k_desc(&info, 10);
        let mut a = sel.clone();
        a.sort_unstable();
        let mut b = want.clone();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn selection_is_diverse_across_clusters() {
        // Uniform informativeness -> selection should spread across the 5
        // fixture clusters rather than collapse into one.
        let mut fx = Fixture::new(200, 8, 33);
        for i in 0..200 {
            let r = fx.scores.row_mut(i);
            r[1] = 0.5; // constant margin
        }
        let sel = Dbal { beta: 10, iters: 8 }.select(&fx.ctx(), 10).unwrap();
        let clusters: std::collections::HashSet<usize> = sel.iter().map(|i| i % 5).collect();
        assert!(clusters.len() >= 4, "selection collapsed: {sel:?}");
    }

    #[test]
    fn small_pools_degenerate_gracefully() {
        let fx = Fixture::new(8, 4, 34);
        let sel = Dbal::default().select(&fx.ctx(), 20).unwrap();
        assert_valid_selection(&sel, 8, 20);
    }
}

//! `alaas` — the ALaaS command-line launcher.
//!
//! Subcommands:
//!   serve      start an AL server from a YAML config (Fig 2)
//!   gen-data   synthesize a dataset into the simulated object store dir
//!   query      client: push a generated dataset + query a selection
//!   agent      run the PSHEA auto-selection agent on a dataset
//!   sessions   list a service's sessions + tenancy counters
//!   strategies list the strategy zoo
//!   help       this text
//!
//! Examples:
//!   alaas serve --config examples/example.yml
//!   alaas gen-data --dataset cifarsim --out /tmp/alaas-data --pool 4000
//!   alaas agent --dataset cifarsim --target 0.8 --max-budget 2000
//!
//! The binary is self-contained after `make artifacts` (Python never runs
//! at serve time); without artifacts it falls back to the host backend
//! (`--backend host`) so every command still works.

use std::sync::Arc;

use alaas::agent::{run_pshea, PsheaConfig, PsheaTrace};
use alaas::cache::DataCache;
use alaas::cli::{Args, Schema};
use alaas::cluster::{Coordinator, CoordinatorDeps};
use alaas::config::AlaasConfig;
use alaas::data::DatasetSpec;
use alaas::metrics::Registry;
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::{ArtifactIndex, HostBackend, PjrtBackend, PjrtPool};
use alaas::server::{AlClient, AlServer, ServerDeps, SessionOpts};
use alaas::sim::AlExperiment;
use alaas::store::{ObjectStore, StoreRouter};
use alaas::trainer::TrainConfig;

const SCHEMA: Schema = Schema {
    value_flags: &[
        "config", "dataset", "out", "seed", "pool", "init", "test", "budget",
        "strategy", "target", "max-budget", "round-budget", "addr", "session",
        "backend", "replicas", "rounds", "role", "coordinator", "discover",
        "remote", "id", "limit", "data-dir", "weight", "max-workers",
    ],
    bool_flags: &["verbose", "quiet", "follow"],
};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, &SCHEMA) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{}", usage());
            std::process::exit(2);
        }
    };
    if args.has("verbose") {
        alaas::util::logger::set_level(alaas::util::logger::Level::Debug);
    }
    let result = match args.subcommand.as_str() {
        "serve" => cmd_serve(&args),
        "gen-data" => cmd_gen_data(&args),
        "query" => cmd_query(&args),
        "agent" => cmd_agent(&args),
        "trace" => cmd_trace(&args),
        "sessions" => cmd_sessions(&args),
        "strategies" => {
            for s in alaas::strategies::zoo_names() {
                println!("{s}");
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown subcommand '{other}'\n{}", usage())),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> &'static str {
    "usage: alaas <serve|gen-data|query|agent|sessions|trace|strategies|help> [flags]\n\
     serve      --config <yml> [--role single|worker|coordinator] [--coordinator host:port]\n\
     \u{20}          [--discover host:port] = join the coordinator via heartbeat/lease\n\
     \u{20}          membership ([cluster.membership] config) instead of a one-shot register\n\
     \u{20}          (worker: --addr <host:port> = address advertised to the coordinator)\n\
     \u{20}          [--data-dir <dir>] = coordinator crash safety: WAL + snapshots under\n\
     \u{20}          <dir>; on restart, sessions and in-flight agent jobs are recovered\n\
     gen-data   --dataset <cifarsim|svhnsim> --out <dir> [--init N --pool N --test N --seed N]\n\
     query      --addr <host:port> --dataset <name> [--budget N --strategy S --seed N]\n\
     \u{20}          [--weight N --max-workers N] = tenancy session options (fair-share\n\
     \u{20}          weight in the admission gate; worker cap for the session's shards)\n\
     sessions   --addr <host:port> = list sessions + tenancy/admission counters\n\
     agent      --dataset <name> [--target A --max-budget N --round-budget N --backend host|pjrt --rounds N]\n\
     \u{20}          [--remote <host:port>] = run PSHEA as a server-side job (agent_start RPC;\n\
     \u{20}          on a coordinator the arms fan out across worker shards)\n\
     \u{20}          [--follow] = with --remote: print every pushed job event verbatim\n\
     \u{20}          (seq + JSON line, the job_subscribe stream; DESIGN.md \u{a7}Events)\n\
     trace      --addr <host:port> [--id <hex-trace-id>] [--limit N]\n\
     \u{20}          without --id: list recent trace roots + the slow-query log;\n\
     \u{20}          with --id: render that trace's span tree with per-stage self-times\n\
     strategies"
}

/// Build the configured compute backend; `pjrt` requires `make artifacts`.
fn make_backend(kind: &str, replicas: usize) -> anyhow::Result<Arc<dyn ComputeBackend>> {
    match kind {
        "host" => Ok(Arc::new(HostBackend::new())),
        "pjrt" => {
            let dir = alaas::runtime::find_artifacts_dir(None)
                .ok_or_else(|| anyhow::anyhow!("artifacts not found; run `make artifacts`"))?;
            let index = Arc::new(ArtifactIndex::load(&dir)?);
            let pool = Arc::new(PjrtPool::new(index, replicas, 64));
            Ok(Arc::new(PjrtBackend::new(pool)))
        }
        other => Err(anyhow::anyhow!("unknown backend '{other}' (host|pjrt)")),
    }
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => AlaasConfig::from_yaml_file(path)?,
        None => AlaasConfig::default(),
    };
    if let Some(dir) = args.get("data-dir") {
        // CLI shorthand for the [durability] section: enable the WAL +
        // snapshot pair under this directory (coordinator role)
        cfg.durability.enabled = true;
        cfg.durability.data_dir = dir.to_string();
    }
    match args.get_or("role", "single") {
        role @ ("single" | "worker") => {
            let backend = make_backend(args.get_or("backend", "pjrt"), cfg.al_worker.replicas)
                .or_else(|e| {
                    eprintln!("pjrt backend unavailable ({e}); falling back to host backend");
                    make_backend("host", cfg.al_worker.replicas)
                })?;
            let deps = ServerDeps {
                store: Arc::new(StoreRouter::new("/", &cfg.store)),
                cache: Arc::new(DataCache::from_config(&cfg.cache)),
                backend,
                metrics: Registry::new(),
            };
            let heartbeat_ms = cfg.cluster.membership.heartbeat_ms;
            let server = AlServer::start(cfg, deps)?;
            println!("alaas {role} listening on {}", server.addr());
            if role == "worker" {
                // the coordinator must be able to dial this address:
                // pass --addr when binding a wildcard interface
                let advertised = args
                    .get("addr")
                    .map(str::to_string)
                    .unwrap_or_else(|| server.addr().to_string());
                if advertised.starts_with("0.0.0.0") {
                    eprintln!(
                        "warning: advertising {advertised}; pass --addr \
                         <routable-host:port> so the coordinator can reach \
                         this worker"
                    );
                }
                if let Some(coord) = args.get("discover") {
                    // live membership: heartbeat/lease auto-discovery —
                    // survives coordinator restarts and rejoins after a
                    // lease loss (DESIGN.md §Cluster)
                    server.discover(coord, Some(&advertised));
                    println!(
                        "heartbeating to coordinator {coord} every {heartbeat_ms}ms \
                         (lease-based membership)"
                    );
                } else if let Some(coord) = args.get("coordinator") {
                    register_with_retry(&advertised, coord);
                } else {
                    println!(
                        "no --discover/--coordinator given; waiting for scan_shard \
                         from a coordinator configured with this address"
                    );
                }
            }
            println!("press ctrl-c to stop");
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        "coordinator" => {
            // the coordinator only refines candidate unions; host math is
            // plenty, but honor an explicit --backend pjrt
            let backend = make_backend(args.get_or("backend", "host"), cfg.al_worker.replicas)
                .or_else(|e| {
                    eprintln!("backend unavailable ({e}); falling back to host backend");
                    make_backend("host", cfg.al_worker.replicas)
                })?;
            let n_workers = cfg.cluster.workers.len();
            let coord = Coordinator::start(
                cfg,
                CoordinatorDeps { backend, metrics: Registry::new() },
            )?;
            println!(
                "alaas coordinator listening on {} ({n_workers} configured workers; \
                 more may join via register)",
                coord.addr()
            );
            println!("press ctrl-c to stop");
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        other => Err(anyhow::anyhow!(
            "unknown role '{other}' (single|worker|coordinator)"
        )),
    }
}

/// Register a worker with its coordinator, retrying while the coordinator
/// boots. Registration failure is not fatal: the worker keeps serving and
/// a coordinator restart can re-register it.
fn register_with_retry(addr: &str, coordinator: &str) {
    for attempt in 1..=10u32 {
        match alaas::cluster::worker::register_with(addr, coordinator) {
            Ok(()) => {
                println!("registered with coordinator at {coordinator}");
                return;
            }
            Err(e) if attempt < 10 => {
                eprintln!("register attempt {attempt} failed ({e}); retrying");
                std::thread::sleep(std::time::Duration::from_millis(500 * attempt as u64));
            }
            Err(e) => eprintln!("could not register with {coordinator}: {e}"),
        }
    }
}

fn cmd_gen_data(args: &Args) -> anyhow::Result<()> {
    let name = args.get_or("dataset", "cifarsim");
    let seed = args.get_usize("seed", 42)? as u64;
    let base = match name {
        "cifarsim" => DatasetSpec::cifarsim(seed),
        "svhnsim" => DatasetSpec::svhnsim(seed),
        other => return Err(anyhow::anyhow!("unknown dataset '{other}'")),
    };
    let (di, dp, dt) = (base.n_init, base.n_pool, base.n_test);
    let spec = base.with_sizes(
        args.get_usize("init", di)?,
        args.get_usize("pool", dp)?,
        args.get_usize("test", dt)?,
    );
    let out = args.get("out").ok_or_else(|| anyhow::anyhow!("--out <dir> required"))?;
    let store: Arc<dyn ObjectStore> = Arc::new(alaas::store::LocalFsStore::new(out)?);
    let manifest = alaas::data::generate_into_store(&spec, &store, "file", name);
    println!(
        "generated {}: init={} pool={} test={} -> {out}/{name}",
        spec.name,
        manifest.init.len(),
        manifest.pool.len(),
        manifest.test.len()
    );
    Ok(())
}

/// Generate a dataset under a temp dir with absolute `file://` URIs so
/// both the client and a server process can read it; returns the
/// manifest plus the oracle. Shared by `query` and `agent --remote`.
fn generate_local_dataset(
    name: &str,
    seed: u64,
    init: usize,
    pool: usize,
    test: usize,
    tag: &str,
) -> anyhow::Result<(alaas::store::Manifest, alaas::data::Oracle)> {
    let dir = std::env::temp_dir().join(format!("alaas-{tag}-{seed}"));
    let store: Arc<dyn ObjectStore> = Arc::new(alaas::store::LocalFsStore::new(&dir)?);
    let spec = match name {
        "cifarsim" => DatasetSpec::cifarsim(seed),
        "svhnsim" => DatasetSpec::svhnsim(seed),
        other => return Err(anyhow::anyhow!("unknown dataset '{other}'")),
    }
    .with_sizes(init, pool, test);
    let mut manifest = alaas::data::generate_into_store(&spec, &store, "file", name);
    // rewrite URIs to absolute file paths
    let rewrite = |refs: &mut Vec<alaas::store::SampleRef>| {
        for r in refs.iter_mut() {
            let rel = r.uri.trim_start_matches("file://");
            r.uri = format!("file://{}/{}", dir.display(), rel);
        }
    };
    rewrite(&mut manifest.init);
    rewrite(&mut manifest.pool);
    rewrite(&mut manifest.test);
    let oracle = alaas::data::Oracle::load(&store, name)?;
    Ok((manifest, oracle))
}

fn cmd_query(args: &Args) -> anyhow::Result<()> {
    let addr = args.get("addr").ok_or_else(|| anyhow::anyhow!("--addr required"))?;
    let name = args.get_or("dataset", "cifarsim");
    let seed = args.get_usize("seed", 42)? as u64;
    let budget = args.get_usize("budget", 100)?;
    let strategy = args.get("strategy");

    let (manifest, oracle) = generate_local_dataset(
        name,
        seed,
        args.get_usize("init", 200)?,
        args.get_usize("pool", 1000)?,
        args.get_usize("test", 0)?,
        "query",
    )?;
    let init_ids: Vec<u32> = manifest.init.iter().map(|s| s.id).collect();
    let init_labels = oracle.label(&init_ids);

    let mut client = AlClient::connect(addr)?;
    client.ping()?;
    // explicit session lifecycle (DESIGN.md §Tenancy): create a handle,
    // push/query through it, and close to release the quota slot
    let opts = SessionOpts {
        weight: args.get_usize("weight", 1)? as u64,
        max_workers: args.get_usize("max-workers", 0)?,
    };
    let mut session = client.create_session(args.get_or("session", "cli"), opts)?;
    session.push(&manifest, Some(&init_labels))?;
    let t0 = std::time::Instant::now();
    let (selected, strat, select_ms) = session.query(budget, strategy)?;
    println!(
        "selected {} samples with {strat} in {:.1}ms (select phase {select_ms:.1}ms)",
        selected.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    for s in selected.iter().take(10) {
        println!("  id={} {}", s.id, s.uri);
    }
    if selected.len() > 10 {
        println!("  ... {} more", selected.len() - 10);
    }
    session.close()?;
    Ok(())
}

/// `sessions --addr <host:port>`: the tenancy control plane — session
/// registry, admission-gate counters, and per-session data footprints
/// (DESIGN.md §Tenancy).
fn cmd_sessions(args: &Args) -> anyhow::Result<()> {
    use alaas::json::Value;
    let addr = args.get("addr").ok_or_else(|| anyhow::anyhow!("--addr required"))?;
    let mut client = AlClient::connect(addr)?;
    let v = client.service_stats()?;
    let b = |k: &str| v.get(k).and_then(Value::as_bool).unwrap_or(false);
    let n = |k: &str| v.get(k).and_then(Value::as_i64).unwrap_or(0);
    println!(
        "tenancy {} on {addr}: {} session(s) ({} active), quota {}",
        if b("tenancy_enabled") { "enabled" } else { "disabled" },
        n("sessions_total"),
        n("sessions_active"),
        n("max_sessions"),
    );
    println!(
        "admission gate: {} running, {} queued, {} admitted, {} shed",
        n("running"),
        n("queued"),
        n("admitted_total"),
        n("shed_total"),
    );
    let sessions = v.get("sessions").and_then(Value::as_array).unwrap_or(&[]);
    if sessions.is_empty() {
        return Ok(());
    }
    println!(
        "{:<24} {:>6} {:>8} {:>8} {:>6} {:>9} {:>6} {:>6}",
        "name", "weight", "explicit", "rows", "shards", "admitted", "shed", "queued"
    );
    for s in sessions {
        let sn = |k: &str| s.get(k).and_then(Value::as_i64).unwrap_or(0);
        println!(
            "{:<24} {:>6} {:>8} {:>8} {:>6} {:>9} {:>6} {:>6}",
            s.get("name").and_then(Value::as_str).unwrap_or("?"),
            sn("weight"),
            s.get("explicit").and_then(Value::as_bool).unwrap_or(false),
            sn("rows"),
            sn("shards"),
            sn("admitted"),
            sn("shed"),
            sn("queued"),
        );
    }
    Ok(())
}

/// `trace --addr <host:port> [--id <hex>] [--limit N]`: the queryable
/// trace plane (DESIGN.md §Observability). Without `--id` it lists the
/// newest trace roots and the slow-query log; with `--id` it fetches the
/// assembled end-to-end span tree (worker subtrees included) and renders
/// it with per-stage self-times.
fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    use alaas::json::Value;
    let addr = args.get("addr").ok_or_else(|| anyhow::anyhow!("--addr required"))?;
    let mut client = AlClient::connect(addr)?;
    if let Some(raw) = args.get("id") {
        let id = u64::from_str_radix(raw.trim_start_matches("0x"), 16)
            .map_err(|_| anyhow::anyhow!("bad trace id '{raw}' (hex, as logs print it)"))?;
        let spans = client.trace_get(id)?;
        if spans.is_empty() {
            return Err(anyhow::anyhow!(
                "trace {id:012x} not found on {addr} (evicted, or never recorded)"
            ));
        }
        print!("{}", alaas::trace::render_tree(&spans));
        return Ok(());
    }
    let v = client.trace_recent(args.get_usize("limit", 0)?)?;
    if !v.get("enabled").and_then(Value::as_bool).unwrap_or(false) {
        println!("tracing is disabled on {addr} ([observability] trace = false)");
    }
    let roots = v.get("roots").and_then(Value::as_array).unwrap_or(&[]);
    println!("{} recent trace roots on {addr}:", roots.len());
    for r in roots {
        let id = r.get("trace").and_then(Value::as_i64).unwrap_or(0) as u64;
        let name = r.get("name").and_then(Value::as_str).unwrap_or("?");
        let dur = r.get("dur_us").and_then(Value::as_usize).unwrap_or(0);
        println!("  {id:012x}  {name}  {dur}us");
    }
    let slow = v.get("slow").and_then(Value::as_array).unwrap_or(&[]);
    if !slow.is_empty() {
        let thresh = v.get("slow_query_ms").and_then(Value::as_usize).unwrap_or(0);
        println!("slow queries (root span > {thresh}ms, retained verbatim):");
        for e in slow {
            let id = e.get("trace").and_then(Value::as_i64).unwrap_or(0) as u64;
            let name = e.get("name").and_then(Value::as_str).unwrap_or("?");
            let dur = e.get("dur_ms").and_then(Value::as_usize).unwrap_or(0);
            let spans = e.get("spans").and_then(Value::as_usize).unwrap_or(0);
            println!("  {id:012x}  {name}  {dur}ms ({spans} spans)");
        }
    }
    println!("inspect one with: alaas trace --addr {addr} --id <hex-trace-id>");
    Ok(())
}

fn print_trace(trace: &PsheaTrace) {
    for r in 0..trace.rounds {
        println!("round {r}:");
        for rec in trace.round(r) {
            println!(
                "  {:18} acc {:.4} pred-next {} {}",
                rec.strategy,
                rec.accuracy,
                rec.predicted_next.map(|p| format!("{p:.4}")).unwrap_or_else(|| "-".into()),
                if rec.eliminated { "ELIMINATED" } else { "" }
            );
        }
    }
    println!(
        "stop: {:?} after {} rounds, budget {} labels, best accuracy {:.4}",
        trace.stop, trace.rounds, trace.total_budget, trace.best_accuracy
    );
    println!("recommended strategy: {}", trace.recommendation().unwrap_or("(none)"));
}

/// `agent --remote <addr>`: run PSHEA as a server-side job — push a local
/// dataset, `agent_start`, follow the job's push-event stream, print the
/// final trace. Against a coordinator the candidate arms evaluate across
/// the cluster. `--follow` prints every pushed event verbatim (one JSON
/// line per event) instead of the per-round summary; either way the
/// progress display is driven entirely by `job_subscribe` pushes — the
/// old `agent_status` sleep-poll loop is gone.
fn cmd_agent_remote(args: &Args, addr: &str) -> anyhow::Result<()> {
    let name = args.get_or("dataset", "cifarsim");
    let seed = args.get_usize("seed", 42)? as u64;
    let (manifest, oracle) = generate_local_dataset(
        name,
        seed,
        args.get_usize("init", 300)?,
        args.get_usize("pool", 2000)?,
        args.get_usize("test", 500)?,
        "agent",
    )?;
    let init_ids: Vec<u32> = manifest.init.iter().map(|s| s.id).collect();
    let init_labels = oracle.label(&init_ids);
    let pool_ids: Vec<u32> = manifest.pool.iter().map(|s| s.id).collect();
    let pool_labels = oracle.eval_labels(&pool_ids);
    let test_ids: Vec<u32> = manifest.test.iter().map(|s| s.id).collect();
    let test_labels = oracle.eval_labels(&test_ids);

    let cfg = PsheaConfig {
        target_accuracy: args.get_f64("target", 0.95)?,
        max_budget: args.get_usize("max-budget", 10_000)?,
        round_budget: args.get_usize("round-budget", 200)?,
        max_rounds: args.get_usize("rounds", 8)?,
        ..Default::default()
    };
    let strategies: Vec<String> =
        alaas::strategies::candidate_names().into_iter().map(str::to_string).collect();

    let mut client = AlClient::connect(addr)?;
    client.ping()?;
    // session handle for push + job start; detach (not drop) before
    // following the stream — dropping would close the session under the
    // running job
    let mut session = client
        .create_session(args.get_or("session", "agent-cli"), SessionOpts::default())?;
    session.push(&manifest, Some(&init_labels))?;
    let job = session.agent_start(&strategies, &cfg, &pool_labels, &test_labels, seed)?;
    let (_, token) = session.detach();
    println!("agent job {job} started on {addr} ({} candidate arms)", strategies.len());

    follow_job(&mut client, &job, args.has("follow"));
    let trace = client.agent_result(&job, std::time::Duration::from_secs(3600))?;
    print_trace(&trace);
    client.close_session(&token)?;
    Ok(())
}

/// Drain a job's push-event stream to stdout until the server ends it.
/// `raw` (`--follow`) prints every event as a `seq\tjson` line; otherwise
/// per-round summary lines are rendered from the same events. Resumes
/// from the last consumed sequence number across connection drops
/// (coordinator crash-restart included), so the printed stream has no
/// gaps or duplicates. Best-effort: a peer without the multiplexed wire
/// degrades to the blocking `agent_result` wait that follows.
fn follow_job(client: &mut AlClient, job: &str, raw: bool) {
    let mut cursor = 0u64;
    let mut dropped = 0u32;
    loop {
        let stream = match client.subscribe_job(job, cursor) {
            Ok(s) => s,
            Err(e) => {
                dropped += 1;
                if dropped > 5 {
                    eprintln!("event stream unavailable ({e}); waiting for the result");
                    return;
                }
                std::thread::sleep(std::time::Duration::from_millis(200 * dropped as u64));
                continue;
            }
        };
        dropped = 0;
        let mut broke = false;
        for item in stream {
            match item {
                Ok(ev) => {
                    cursor = ev.seq;
                    render_job_event(ev.seq, &ev.value, raw);
                }
                Err(e) => {
                    // connection died mid-stream: resubscribe from the
                    // cursor (the re-dial happens inside subscribe_job)
                    eprintln!("event stream interrupted ({e}); resubscribing");
                    broke = true;
                    break;
                }
            }
        }
        if !broke {
            return;
        }
    }
}

fn render_job_event(seq: u64, ev: &alaas::json::Value, raw: bool) {
    if raw {
        println!("{seq}\t{}", alaas::json::to_string(ev));
        return;
    }
    match ev.get("t").and_then(|v| v.as_str()).unwrap_or("") {
        "job_record" => {
            if let Some(rec) = ev.get("record") {
                let round = rec.get("round").and_then(|v| v.as_usize()).unwrap_or(0);
                let strategy =
                    rec.get("strategy").and_then(|v| v.as_str()).unwrap_or("?");
                let acc = rec.get("accuracy").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let spent =
                    rec.get("budget_spent").and_then(|v| v.as_usize()).unwrap_or(0);
                println!("  round {round} {strategy:18} acc {acc:.4} ({spent} labels)");
            }
        }
        "job_elim" => {
            let strategy = ev.get("strategy").and_then(|v| v.as_str()).unwrap_or("?");
            let round = ev.get("round").and_then(|v| v.as_usize()).unwrap_or(0);
            println!("  round {round} {strategy:18} ELIMINATED");
        }
        "job_resume" => {
            let from = ev.get("from_round").and_then(|v| v.as_usize()).unwrap_or(0);
            println!("  job resumed from round {from} (server restart)");
        }
        "job_cancel" => println!("  job cancelled"),
        "job_done" => {
            let status = ev.get("status").and_then(|v| v.as_str()).unwrap_or("?");
            println!("  job finished: {status}");
        }
        // per-round spends and round markers are summary noise
        _ => {}
    }
}

fn cmd_agent(args: &Args) -> anyhow::Result<()> {
    if let Some(addr) = args.get("remote") {
        return cmd_agent_remote(args, addr);
    }
    let name = args.get_or("dataset", "cifarsim");
    let seed = args.get_usize("seed", 42)? as u64;
    let spec = match name {
        "cifarsim" => DatasetSpec::cifarsim(seed),
        "svhnsim" => DatasetSpec::svhnsim(seed),
        other => return Err(anyhow::anyhow!("unknown dataset '{other}'")),
    }
    .with_sizes(
        args.get_usize("init", 300)?,
        args.get_usize("pool", 2000)?,
        args.get_usize("test", 500)?,
    );
    let backend = make_backend(args.get_or("backend", "pjrt"), args.get_usize("replicas", 2)?)
        .or_else(|e| {
            eprintln!("pjrt backend unavailable ({e}); falling back to host backend");
            make_backend("host", 2)
        })?;

    println!("generating {name} (seed {seed})...");
    let gen = alaas::data::generate(&spec);
    println!("embedding {} samples through {}...", gen.images.len(), backend.name());
    let mut exp = AlExperiment::from_generated(
        backend,
        &gen,
        spec.num_classes,
        TrainConfig::default(),
        seed,
    )?;

    let cfg = PsheaConfig {
        target_accuracy: args.get_f64("target", 0.95)?,
        max_budget: args.get_usize("max-budget", 10_000)?,
        round_budget: args.get_usize("round-budget", 200)?,
        max_rounds: args.get_usize("rounds", 8)?,
        ..Default::default()
    };
    let strategies: Vec<String> =
        alaas::strategies::candidate_names().into_iter().map(str::to_string).collect();
    println!(
        "PSHEA: {} candidates, target {:.2}, round budget {}, max budget {}",
        strategies.len(),
        cfg.target_accuracy,
        cfg.round_budget,
        cfg.max_budget
    );
    let trace = run_pshea(&mut exp, &strategies, &cfg)?;
    print_trace(&trace);
    Ok(())
}

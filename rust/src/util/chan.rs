//! Bounded MPMC channel substrate (no `tokio`/`crossbeam-channel` offline).
//!
//! This is the backbone of the stage-level pipeline (Fig 3c): each stage
//! boundary is one of these channels, and the bound is the backpressure —
//! a fast downloader cannot run arbitrarily far ahead of the embedding
//! workers, which is exactly the waiting-time control the paper's pipeline
//! section describes.
//!
//! Semantics:
//! * `send` blocks while full, fails with `SendError` once all receivers
//!   are gone or the channel is closed.
//! * `recv` blocks while empty, returns `None` once the channel is closed
//!   (or all senders dropped) *and* drained.
//! * Any number of `Sender`/`Receiver` clones; drop tracking is automatic.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Error returned by `send` when the channel can no longer accept items.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

struct Shared<T> {
    q: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    senders: AtomicUsize,
    receivers: AtomicUsize,
    cap: usize,
}

struct Inner<T> {
    buf: VecDeque<T>,
    closed: bool,
}

/// Sending half of a bounded channel.
pub struct Sender<T>(Arc<Shared<T>>);
/// Receiving half of a bounded channel.
pub struct Receiver<T>(Arc<Shared<T>>);

/// Create a bounded channel with capacity `cap` (>= 1).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    assert!(cap >= 1, "channel capacity must be >= 1");
    let shared = Arc::new(Shared {
        q: Mutex::new(Inner { buf: VecDeque::with_capacity(cap), closed: false }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        senders: AtomicUsize::new(1),
        receivers: AtomicUsize::new(1),
        cap,
    });
    (Sender(shared.clone()), Receiver(shared))
}

impl<T> Sender<T> {
    /// Blocking send. Returns the value back if the channel is closed or
    /// every receiver is gone.
    pub fn send(&self, v: T) -> Result<(), SendError<T>> {
        let sh = &self.0;
        let mut g = sh.q.lock().unwrap();
        loop {
            if g.closed || sh.receivers.load(Ordering::Acquire) == 0 {
                return Err(SendError(v));
            }
            if g.buf.len() < sh.cap {
                g.buf.push_back(v);
                drop(g);
                sh.not_empty.notify_one();
                return Ok(());
            }
            g = sh.not_full.wait(g).unwrap();
        }
    }

    /// Non-blocking send; `Err` carries the value back when full/closed.
    pub fn try_send(&self, v: T) -> Result<(), SendError<T>> {
        let sh = &self.0;
        let mut g = sh.q.lock().unwrap();
        if g.closed || sh.receivers.load(Ordering::Acquire) == 0 || g.buf.len() >= sh.cap {
            return Err(SendError(v));
        }
        g.buf.push_back(v);
        drop(g);
        sh.not_empty.notify_one();
        Ok(())
    }

    /// Close the channel: receivers drain what's buffered, then get `None`.
    pub fn close(&self) {
        let mut g = self.0.q.lock().unwrap();
        g.closed = true;
        drop(g);
        self.0.not_empty.notify_all();
        self.0.not_full.notify_all();
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` once closed (or senderless) and drained.
    pub fn recv(&self) -> Option<T> {
        let sh = &self.0;
        let mut g = sh.q.lock().unwrap();
        loop {
            if let Some(v) = g.buf.pop_front() {
                drop(g);
                sh.not_full.notify_one();
                return Some(v);
            }
            if g.closed || sh.senders.load(Ordering::Acquire) == 0 {
                return None;
            }
            g = sh.not_empty.wait(g).unwrap();
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let sh = &self.0;
        let mut g = sh.q.lock().unwrap();
        let v = g.buf.pop_front();
        if v.is_some() {
            drop(g);
            sh.not_full.notify_one();
        }
        v
    }

    /// Receive with a deadline; `Ok(None)` means closed+drained, `Err(())`
    /// means timed out.
    pub fn recv_timeout(&self, timeout: std::time::Duration) -> Result<Option<T>, ()> {
        let sh = &self.0;
        let deadline = std::time::Instant::now() + timeout;
        let mut g = sh.q.lock().unwrap();
        loop {
            if let Some(v) = g.buf.pop_front() {
                drop(g);
                sh.not_full.notify_one();
                return Ok(Some(v));
            }
            if g.closed || sh.senders.load(Ordering::Acquire) == 0 {
                return Ok(None);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(());
            }
            let (guard, res) = sh.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
            if res.timed_out() && g.buf.is_empty() {
                if g.closed || sh.senders.load(Ordering::Acquire) == 0 {
                    return Ok(None);
                }
                return Err(());
            }
        }
    }

    /// Number of currently buffered items (racy; for metrics only).
    pub fn len(&self) -> usize {
        self.0.q.lock().unwrap().buf.len()
    }

    /// True when no items are buffered (racy; for metrics only).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.0.senders.fetch_add(1, Ordering::AcqRel);
        Sender(self.0.clone())
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.0.receivers.fetch_add(1, Ordering::AcqRel);
        Receiver(self.0.clone())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        if self.0.senders.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.0.not_empty.notify_all();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        if self.0.receivers.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.0.not_full.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_single_thread() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let got: Vec<i32> = std::iter::from_fn(|| rx.recv()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn blocks_at_capacity_then_resumes() {
        let (tx, rx) = bounded(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert!(tx.try_send(3).is_err(), "full channel rejects try_send");
        let h = thread::spawn(move || tx.send(3)); // blocks until a recv
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv(), Some(1));
        h.join().unwrap().unwrap();
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), Some(3));
    }

    #[test]
    fn recv_none_after_all_senders_drop() {
        let (tx, rx) = bounded::<i32>(4);
        let tx2 = tx.clone();
        tx.send(1).unwrap();
        drop(tx);
        tx2.send(2).unwrap();
        drop(tx2);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn send_fails_after_all_receivers_drop() {
        let (tx, rx) = bounded::<i32>(4);
        drop(rx);
        assert_eq!(tx.send(7), Err(SendError(7)));
    }

    #[test]
    fn close_drains_then_stops() {
        let (tx, rx) = bounded(4);
        tx.send(1).unwrap();
        tx.close();
        assert!(tx.send(2).is_err());
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        let (tx, rx) = bounded::<u64>(16);
        let producers = 4;
        let per = 500u64;
        let mut handles = vec![];
        for p in 0..producers {
            let tx = tx.clone();
            handles.push(thread::spawn(move || {
                for i in 0..per {
                    tx.send(p * 10_000 + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut consumers = vec![];
        for _ in 0..3 {
            let rx = rx.clone();
            consumers.push(thread::spawn(move || {
                let mut got = vec![];
                while let Some(v) = rx.recv() {
                    got.push(v);
                }
                got
            }));
        }
        drop(rx);
        for h in handles {
            h.join().unwrap();
        }
        let mut all: Vec<u64> = consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        let mut want: Vec<u64> =
            (0..producers).flat_map(|p| (0..per).map(move |i| p * 10_000 + i)).collect();
        want.sort_unstable();
        assert_eq!(all, want);
    }

    #[test]
    fn recv_timeout_times_out_then_succeeds() {
        let (tx, rx) = bounded::<i32>(1);
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)), Err(()));
        tx.send(5).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)), Ok(Some(5)));
    }
}

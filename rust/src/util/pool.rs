//! Thread-pool substrate (no `tokio`/`rayon` offline).
//!
//! A fixed set of workers pulling boxed jobs from a bounded channel. Used
//! for the fetch/preprocess stages of the pipeline and for the RPC server's
//! connection handling. Panics inside a job are caught and counted so a
//! poisoned sample cannot take a stage down (failure-injection tests rely
//! on this).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::chan::{bounded, Sender};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    panics: Arc<AtomicU64>,
}

impl ThreadPool {
    /// Spawn `n` workers (>= 1) with `queue` pending-job slots.
    pub fn new(name: &str, n: usize, queue: usize) -> Self {
        assert!(n >= 1, "thread pool needs >= 1 worker");
        let (tx, rx) = bounded::<Job>(queue.max(1));
        let panics = Arc::new(AtomicU64::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = rx.clone();
                let panics = panics.clone();
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                panics.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, panics }
    }

    /// Submit a job; blocks if the queue is full (backpressure).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .unwrap_or_else(|_| panic!("thread pool queue closed"));
    }

    /// Number of jobs that panicked since startup.
    pub fn panic_count(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }

    /// Stop accepting jobs, run out the queue, join all workers.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if let Some(tx) = self.tx.take() {
            tx.close();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Run `f` over `items` with up to `n` scoped workers, collecting results
/// in input order. Panics propagate. This is the parallel-map used by the
/// dataset generator and the distance tiling driver.
pub fn scoped_map<T: Sync, R: Send>(
    n: usize,
    items: &[T],
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let n = n.max(1).min(items.len().max(1));
    let next = AtomicU64::new(0);
    // Each worker collects (index, result) pairs; merged and re-ordered at
    // the end. Work-stealing via the shared atomic counter keeps load even
    // when per-item cost varies (e.g. store GETs with latency jitter).
    let mut parts: Vec<Vec<(usize, R)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|_| {
                s.spawn(|| {
                    let mut got = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed) as usize;
                        if i >= items.len() {
                            break;
                        }
                        got.push((i, f(&items[i])));
                    }
                    got
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("scoped_map worker")).collect()
    });
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    for part in parts.drain(..) {
        for (i, r) in part {
            out[i] = Some(r);
        }
    }
    out.into_iter().map(|o| o.expect("scoped_map slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Mutex;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new("t", 4, 8);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn survives_panicking_jobs() {
        let pool = ThreadPool::new("t", 2, 4);
        let ok = Arc::new(AtomicUsize::new(0));
        for i in 0..20 {
            let ok = ok.clone();
            pool.execute(move || {
                if i % 3 == 0 {
                    panic!("injected failure");
                }
                ok.fetch_add(1, Ordering::Relaxed);
            });
        }
        let panics_expected = (0..20).filter(|i| i % 3 == 0).count() as u64;
        // shutdown drains the queue first
        let panics = {
            let p = pool.panics.clone();
            pool.shutdown();
            p.load(Ordering::Relaxed)
        };
        assert_eq!(panics, panics_expected);
        assert_eq!(ok.load(Ordering::Relaxed), 20 - panics_expected as usize);
    }

    #[test]
    fn drop_joins_cleanly() {
        let hits = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new("t", 2, 4);
            for _ in 0..10 {
                let h = hits.clone();
                pool.execute(move || {
                    h.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop = shutdown
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn scoped_map_preserves_order() {
        let items: Vec<u64> = (0..200).collect();
        let out = scoped_map(8, &items, |&x| x * x);
        assert_eq!(out, items.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_empty_and_single() {
        assert!(scoped_map(4, &Vec::<u32>::new(), |&x| x).is_empty());
        assert_eq!(scoped_map(4, &[7u32], |&x| x + 1), vec![8]);
    }

    #[test]
    fn scoped_map_uses_multiple_threads() {
        let tids = Mutex::new(std::collections::HashSet::new());
        let items: Vec<u32> = (0..64).collect();
        scoped_map(4, &items, |_| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            tids.lock().unwrap().insert(std::thread::current().id());
        });
        assert!(tids.into_inner().unwrap().len() > 1);
    }
}

//! Tiny leveled logger (no `log`/`env_logger` wiring needed at runtime).
//!
//! Level comes from `ALAAS_LOG` (`error|warn|info|debug|trace`, default
//! `info`). Output goes to stderr so bench tables on stdout stay clean.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn max_level() -> u8 {
    let v = MAX_LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let from_env = std::env::var("ALAAS_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info);
    MAX_LEVEL.store(from_env as u8, Ordering::Relaxed);
    from_env as u8
}

/// Override the level programmatically (tests, CLI `--verbose`).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// True when `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= max_level()
}

/// Emit a log line. Prefer the `log_*!` macros.
pub fn log(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let secs = now.as_secs();
    let millis = now.subsec_millis();
    eprintln!("[{secs}.{millis:03} {} {target}] {msg}", level.as_str());
}

#[macro_export]
macro_rules! log_error { ($t:expr, $($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, $t, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($t:expr, $($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, $t, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($t:expr, $($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, $t, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($t:expr, $($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, $t, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_trace { ($t:expr, $($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Trace, $t, format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn set_level_gates_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}

//! Tiny leveled logger (no `log`/`env_logger` wiring needed at runtime).
//!
//! Level comes from `ALAAS_LOG` (`error|warn|info|debug|trace`, default
//! `info`). Output goes to stderr so bench tables on stdout stay clean.
//!
//! Format comes from `ALAAS_LOG_FORMAT` (`text|json`, default `text`);
//! the env var wins over `[observability] log_format` so an operator can
//! flip a running deployment's output without editing config. JSON mode
//! emits one object per line: `{ts, level, target, trace_id?, msg}`.
//!
//! Every line carries the thread's current trace id (installed by
//! `trace::SpanGuard`), so grepping one id reconstructs a request across
//! coordinator and workers.

use std::cell::Cell;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Format {
    Text = 0,
    Json = 1,
}

impl Format {
    pub fn parse(s: &str) -> Option<Format> {
        match s.to_ascii_lowercase().as_str() {
            "text" => Some(Format::Text),
            "json" => Some(Format::Json),
            _ => None,
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized
static FORMAT: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

thread_local! {
    static TRACE: Cell<u64> = const { Cell::new(0) };
}

fn max_level() -> u8 {
    let v = MAX_LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let from_env = std::env::var("ALAAS_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info);
    MAX_LEVEL.store(from_env as u8, Ordering::Relaxed);
    from_env as u8
}

fn format() -> Format {
    let v = FORMAT.load(Ordering::Relaxed);
    if v != 255 {
        return if v == Format::Json as u8 { Format::Json } else { Format::Text };
    }
    let from_env = std::env::var("ALAAS_LOG_FORMAT")
        .ok()
        .and_then(|s| Format::parse(&s))
        .unwrap_or(Format::Text);
    FORMAT.store(from_env as u8, Ordering::Relaxed);
    from_env
}

/// Override the level programmatically (tests, CLI `--verbose`).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Override the format programmatically.
pub fn set_format(f: Format) {
    FORMAT.store(f as u8, Ordering::Relaxed);
}

/// Apply `[observability] log_format` — a no-op when `ALAAS_LOG_FORMAT`
/// is set, since the env var outranks config.
pub fn set_format_from_config(s: &str) {
    if std::env::var("ALAAS_LOG_FORMAT").is_ok() {
        return;
    }
    if let Some(f) = Format::parse(s) {
        set_format(f);
    }
}

/// Install `trace_id` as this thread's current trace (0 = none);
/// returns the previous value. Managed by `trace::SpanGuard` — call it
/// directly only when threading a context by hand.
pub fn set_trace(trace_id: u64) -> u64 {
    TRACE.with(|t| t.replace(trace_id))
}

/// The trace id stamped on this thread's log lines (0 = none).
pub fn current_trace() -> u64 {
    TRACE.with(|t| t.get())
}

/// True when `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= max_level()
}

/// Emit a log line. Prefer the `log_*!` macros.
pub fn log(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let secs = now.as_secs();
    let millis = now.subsec_millis();
    let trace = current_trace();
    match format() {
        Format::Text => {
            if trace != 0 {
                eprintln!(
                    "[{secs}.{millis:03} {} {target} t:{trace:012x}] {msg}",
                    level.as_str()
                );
            } else {
                eprintln!("[{secs}.{millis:03} {} {target}] {msg}", level.as_str());
            }
        }
        Format::Json => {
            use crate::json::{Map, Value};
            let mut m = Map::new();
            m.insert("ts", Value::from(secs as f64 + f64::from(millis) / 1_000.0));
            m.insert("level", Value::from(level.as_str().trim_end()));
            m.insert("target", Value::from(target));
            if trace != 0 {
                m.insert("trace_id", Value::from(format!("{trace:012x}")));
            }
            m.insert("msg", Value::from(msg.to_string()));
            eprintln!("{}", crate::json::to_string(&Value::Object(m)));
        }
    }
}

#[macro_export]
macro_rules! log_error { ($t:expr, $($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, $t, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($t:expr, $($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, $t, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($t:expr, $($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, $t, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($t:expr, $($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, $t, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_trace { ($t:expr, $($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Trace, $t, format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn set_level_gates_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn format_parse() {
        assert_eq!(Format::parse("json"), Some(Format::Json));
        assert_eq!(Format::parse("TEXT"), Some(Format::Text));
        assert_eq!(Format::parse("xml"), None);
    }

    #[test]
    fn trace_slot_is_per_thread_and_restorable() {
        assert_eq!(current_trace(), 0);
        let prev = set_trace(0xabc);
        assert_eq!(prev, 0);
        assert_eq!(current_trace(), 0xabc);
        std::thread::scope(|s| {
            s.spawn(|| assert_eq!(current_trace(), 0, "trace slot must not leak across threads"));
        });
        set_trace(prev);
        assert_eq!(current_trace(), 0);
    }
}

//! Randomized property-test harness (proptest is not in the offline
//! registry; DESIGN.md §Substitutions).
//!
//! `check("name", cases, |rng| { ... })` runs a property closure `cases`
//! times with derived-but-reproducible rngs. On failure it panics with the
//! case seed so the exact counterexample replays with
//! `check_one("name", seed, f)`. `ALAAS_PROP_CASES` scales the case count
//! globally (soak runs).

use super::rng::Rng;

/// Outcome of one property case.
pub type PropResult = Result<(), String>;

fn case_count(default_cases: u32) -> u32 {
    std::env::var("ALAAS_PROP_CASES")
        .ok()
        .and_then(|s| s.parse::<u32>().ok())
        .unwrap_or(default_cases)
}

/// Seed for case `i` of property `name` — stable across runs and
/// independent of execution order.
fn case_seed(name: &str, i: u32) -> u64 {
    // FNV-1a over the name, mixed with the case index.
    crate::util::fnv1a(name.as_bytes()) ^ ((i as u64) << 32 | 0x5bd1_e995)
}

/// Run `f` for `cases` randomized cases. Panics on the first failure with
/// the replay seed embedded in the message.
pub fn check(name: &str, cases: u32, f: impl Fn(&mut Rng) -> PropResult) {
    let n = case_count(cases);
    for i in 0..n {
        let seed = case_seed(name, i);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed on case {i}/{n} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single case by seed (use the seed from a `check` failure).
pub fn check_one(name: &str, seed: u64, f: impl Fn(&mut Rng) -> PropResult) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property '{name}' failed on replay seed {seed:#x}: {msg}");
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        check("tautology", 50, |rng| {
            let x = rng.below(100);
            prop_assert!(x < 100, "x={x} out of range");
            Ok(())
        });
    }

    #[test]
    fn failure_panics_with_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always-fails", 5, |_| Err("nope".to_string()))
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("replay seed"), "{msg}");
        assert!(msg.contains("nope"), "{msg}");
    }

    #[test]
    fn case_seeds_are_stable_and_distinct() {
        assert_eq!(case_seed("p", 0), case_seed("p", 0));
        assert_ne!(case_seed("p", 0), case_seed("p", 1));
        assert_ne!(case_seed("p", 0), case_seed("q", 0));
    }

    #[test]
    fn replay_reproduces_case_stream() {
        // The same seed must yield the same rng draws.
        let seed = case_seed("stream", 3);
        let a: Vec<u64> = {
            let mut r = Rng::new(seed);
            (0..10).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(seed);
            (0..10).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }
}

//! In-tree micro/bench harness (criterion is not in the offline registry).
//!
//! Every `rust/benches/*.rs` target is a `harness = false` binary built on
//! this module: `Bench::new("table2").row(...)` measures a closure with
//! warmup + repeated timed runs and prints aligned rows, which
//! EXPERIMENTS.md captures verbatim. Statistical summary: mean, p50, p95,
//! min over runs; throughput helpers convert to items/sec.

use std::time::{Duration, Instant};

/// Result of measuring one closure.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Per-run wall times, sorted ascending.
    pub runs: Vec<Duration>,
}

impl Sample {
    pub fn mean(&self) -> Duration {
        let total: Duration = self.runs.iter().sum();
        total / self.runs.len().max(1) as u32
    }

    pub fn percentile(&self, p: f64) -> Duration {
        if self.runs.is_empty() {
            return Duration::ZERO;
        }
        let idx = ((self.runs.len() - 1) as f64 * p).round() as usize;
        self.runs[idx]
    }

    pub fn min(&self) -> Duration {
        self.runs.first().copied().unwrap_or_default()
    }

    pub fn max(&self) -> Duration {
        self.runs.last().copied().unwrap_or_default()
    }

    /// Population standard deviation in seconds.
    pub fn std_secs(&self) -> f64 {
        let n = self.runs.len().max(1) as f64;
        let mean = self.mean().as_secs_f64();
        let var = self
            .runs
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - mean;
                x * x
            })
            .sum::<f64>()
            / n;
        var.sqrt()
    }

    /// items/sec given `items` processed per run.
    pub fn throughput(&self, items: u64) -> f64 {
        let m = self.mean().as_secs_f64();
        if m <= 0.0 {
            return f64::INFINITY;
        }
        items as f64 / m
    }
}

/// Measure `f` `runs` times after `warmup` unmeasured calls.
pub fn measure(warmup: usize, runs: usize, mut f: impl FnMut()) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(runs);
    for _ in 0..runs.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    Sample { runs: times }
}

/// Adaptive measurement: run `f` until `budget` elapses (at least 3 runs).
pub fn measure_for(budget: Duration, mut f: impl FnMut()) -> Sample {
    let start = Instant::now();
    let mut times = Vec::new();
    while times.len() < 3 || start.elapsed() < budget {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
        if times.len() >= 1000 {
            break;
        }
    }
    times.sort_unstable();
    Sample { runs: times }
}

/// Pretty duration: auto-unit ns/us/ms/s.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// Table printer for bench targets: aligned columns, Markdown-ish output.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let hdr: Vec<String> =
            self.headers.iter().enumerate().map(|(i, h)| format!("{:w$}", h, w = widths[i])).collect();
        println!("| {} |", hdr.join(" | "));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            let cells: Vec<String> =
                row.iter().enumerate().map(|(i, c)| format!("{:w$}", c, w = widths[i])).collect();
            println!("| {} |", cells.join(" | "));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_stats() {
        let s = Sample {
            runs: vec![
                Duration::from_millis(10),
                Duration::from_millis(20),
                Duration::from_millis(30),
            ],
        };
        assert_eq!(s.mean(), Duration::from_millis(20));
        assert_eq!(s.percentile(0.5), Duration::from_millis(20));
        assert_eq!(s.min(), Duration::from_millis(10));
        assert!((s.throughput(100) - 5000.0).abs() < 1.0);
    }

    #[test]
    fn measure_counts_runs() {
        let mut hits = 0;
        let s = measure(2, 5, || hits += 1);
        assert_eq!(hits, 7);
        assert_eq!(s.runs.len(), 5);
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert!(fmt_dur(Duration::from_micros(1500)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }

    #[test]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["only-one".into()])
        }));
        assert!(r.is_err());
    }
}

//! Top-k selection over f32 scores — the inner loop of every
//! uncertainty-based strategy (select the `budget` most-uncertain samples
//! from a pool of hundreds of thousands without sorting the whole pool).
//!
//! A bounded binary min-heap keyed by score: O(N log k) instead of
//! O(N log N). Ties break on index for full determinism.

use std::cmp::Ordering;

#[derive(Debug, Clone, Copy, PartialEq)]
struct Entry {
    score: f32,
    idx: usize,
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Total order on f32: scores first (NaN sorts lowest), then index
        // descending so the heap root is the *worst* kept element.
        self.score
            .partial_cmp(&other.score)
            .unwrap_or_else(|| match (self.score.is_nan(), other.score.is_nan()) {
                (true, true) => Ordering::Equal,
                (true, false) => Ordering::Less,
                (false, true) => Ordering::Greater,
                _ => unreachable!(),
            })
            .then_with(|| other.idx.cmp(&self.idx))
    }
}

/// Indices of the `k` largest scores, ordered best-first.
/// `k > scores.len()` returns everything.
pub fn top_k_desc(scores: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(scores.len());
    if k == 0 {
        return vec![];
    }
    // min-heap of the k best so far (std BinaryHeap is a max-heap, so wrap
    // with Reverse).
    use std::cmp::Reverse;
    let mut heap: std::collections::BinaryHeap<Reverse<Entry>> =
        std::collections::BinaryHeap::with_capacity(k + 1);
    for (idx, &score) in scores.iter().enumerate() {
        let e = Entry { score, idx };
        if heap.len() < k {
            heap.push(Reverse(e));
        } else if e > heap.peek().unwrap().0 {
            heap.pop();
            heap.push(Reverse(e));
        }
    }
    let mut out: Vec<Entry> = heap.into_iter().map(|Reverse(e)| e).collect();
    out.sort_unstable_by(|a, b| b.cmp(a));
    out.into_iter().map(|e| e.idx).collect()
}

/// Indices of the `k` smallest scores, ordered best(smallest)-first.
pub fn top_k_asc(scores: &[f32], k: usize) -> Vec<usize> {
    let neg: Vec<f32> = scores.iter().map(|s| -s).collect();
    top_k_desc(&neg, k)
}

/// Index of the maximum score (first on ties); None on empty.
pub fn argmax(scores: &[f32]) -> Option<usize> {
    let mut best: Option<(usize, f32)> = None;
    for (i, &s) in scores.iter().enumerate() {
        match best {
            None => best = Some((i, s)),
            Some((_, b)) if s > b => best = Some((i, s)),
            _ => {}
        }
    }
    best.map(|(i, _)| i)
}

/// Index of the minimum score (first on ties); None on empty.
pub fn argmin(scores: &[f32]) -> Option<usize> {
    let neg: Vec<f32> = scores.iter().map(|s| -s).collect();
    argmax(&neg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_full_sort() {
        let scores = vec![0.3, 0.9, 0.1, 0.9, 0.5, 0.2, 0.8];
        let got = top_k_desc(&scores, 3);
        assert_eq!(got, vec![1, 3, 6]); // 0.9 (idx1), 0.9 (idx3), 0.8
    }

    #[test]
    fn asc_is_desc_of_negation() {
        let scores = vec![5.0, 1.0, 3.0, 2.0];
        assert_eq!(top_k_asc(&scores, 2), vec![1, 3]);
    }

    #[test]
    fn k_larger_than_n() {
        let scores = vec![1.0, 2.0];
        assert_eq!(top_k_desc(&scores, 10), vec![1, 0]);
    }

    #[test]
    fn k_zero_and_empty() {
        assert!(top_k_desc(&[1.0], 0).is_empty());
        assert!(top_k_desc(&[], 3).is_empty());
    }

    #[test]
    fn nan_never_selected_over_real() {
        let scores = vec![f32::NAN, 0.1, f32::NAN, 0.2];
        assert_eq!(top_k_desc(&scores, 2), vec![3, 1]);
    }

    #[test]
    fn deterministic_tie_break_by_index() {
        let scores = vec![1.0; 6];
        assert_eq!(top_k_desc(&scores, 3), vec![0, 1, 2]);
    }

    #[test]
    fn all_nan_input_is_deterministic_by_index() {
        let scores = vec![f32::NAN; 5];
        assert_eq!(top_k_desc(&scores, 3), vec![0, 1, 2]);
        assert_eq!(top_k_asc(&scores, 3), vec![0, 1, 2]);
    }

    #[test]
    fn nan_fills_only_leftover_slots() {
        // k exceeds the finite count: every finite score is selected
        // before any NaN, in both directions.
        let scores = vec![f32::NAN, 0.4, f32::NAN, 0.2, 0.9];
        assert_eq!(top_k_desc(&scores, 4), vec![4, 1, 3, 0]);
        assert_eq!(top_k_asc(&scores, 4), vec![3, 1, 4, 0]);
    }

    #[test]
    fn nan_never_selected_in_asc_direction() {
        // top_k_asc negates scores; -NaN is still NaN and must still lose
        // to every finite value.
        let scores = vec![f32::NAN, 5.0, 1.0, f32::NAN, 3.0];
        assert_eq!(top_k_asc(&scores, 2), vec![2, 4]);
    }

    #[test]
    fn duplicate_scores_stay_deterministic_at_the_boundary() {
        // the k-th and (k+1)-th best tie: selection must cut on index
        let scores = vec![0.5, 0.9, 0.5, 0.5, 0.1];
        assert_eq!(top_k_desc(&scores, 2), vec![1, 0]);
        assert_eq!(top_k_desc(&scores, 3), vec![1, 0, 2]);
        // repeated runs agree (heap order is an implementation detail)
        for k in 0..=5 {
            assert_eq!(top_k_desc(&scores, k), top_k_desc(&scores, k), "k={k}");
        }
    }

    #[test]
    fn prop_nan_and_duplicates_match_reference_order() {
        crate::util::prop::check("topk-nan-dup", 60, |rng| {
            let n = 1 + rng.below(150);
            let k = rng.below(n + 3);
            let scores: Vec<f32> = (0..n)
                .map(|_| match rng.below(4) {
                    0 => f32::NAN,
                    1 => 0.5, // force duplicates
                    _ => rng.f32(),
                })
                .collect();
            let got = top_k_desc(&scores, k);
            // reference: total order = finite desc, NaN last, ties by index
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| {
                let (x, y) = (scores[a], scores[b]);
                match (x.is_nan(), y.is_nan()) {
                    (true, true) => a.cmp(&b),
                    (true, false) => std::cmp::Ordering::Greater,
                    (false, true) => std::cmp::Ordering::Less,
                    (false, false) => y.partial_cmp(&x).unwrap().then(a.cmp(&b)),
                }
            });
            idx.truncate(k.min(n));
            crate::prop_assert!(got == idx, "n={n} k={k}: {got:?} != {idx:?}");
            // a NaN may appear only after every finite score is taken
            let finite = scores.iter().filter(|s| !s.is_nan()).count();
            for (pos, &i) in got.iter().enumerate() {
                crate::prop_assert!(
                    !scores[i].is_nan() || pos >= finite,
                    "NaN at position {pos} before finite scores ran out"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn argmax_argmin() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), Some(1));
        assert_eq!(argmin(&[1.0, 3.0, 2.0]), Some(0));
        assert_eq!(argmax(&[]), None);
    }

    #[test]
    fn randomized_against_sort() {
        let mut rng = crate::util::rng::Rng::new(42);
        for _ in 0..50 {
            let n = 1 + rng.below(300);
            let k = rng.below(n + 5);
            let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let got = top_k_desc(&scores, k);
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| {
                scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b))
            });
            idx.truncate(k.min(n));
            assert_eq!(got, idx);
        }
    }
}

//! Deterministic PRNG substrate (no `rand` crate in the offline registry).
//!
//! `Rng` is xoshiro256** seeded through SplitMix64 — the standard pairing:
//! SplitMix64 decorrelates arbitrary u64 seeds, xoshiro256** provides the
//! stream. Everything in ALaaS that needs randomness (dataset synthesis,
//! Random strategy, k-means init, property tests) threads one of these
//! through explicitly so every experiment is replayable from its seed.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal sample from Box-Muller.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from an arbitrary seed (any value is fine,
    /// including 0 — SplitMix64 expands it to a full state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream (for per-worker / per-class rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift with rejection to
    /// avoid modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let threshold = n.wrapping_neg() % n; // reject below this to kill bias
        loop {
            let m = (self.next_u64() as u128) * (n as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller (caches the spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = loop {
            let u1 = self.f64();
            if u1 > f64::EPSILON {
                break (u1, self.f64());
            }
        };
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * th.sin());
        r * th.cos()
    }

    /// Standard normal as f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        // Partial Fisher-Yates over an index vec; O(n) alloc, O(k) swaps.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_and_bounded() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            let k = r.below(20);
            let v = r.sample_indices(20, k);
            assert_eq!(v.len(), k);
            let mut s = v.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), k, "indices distinct");
            assert!(v.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(1);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}

//! Minimal dense row-major f32 matrix used across the coordinator
//! (embeddings `[N, D]`, scores `[N, 4]`, head weights `[D, C]`).
//!
//! Not a linear-algebra library: the heavy math lives in the AOT-compiled
//! XLA artifacts; this type only carries data between stages and hosts the
//! small host-fallback kernels in `runtime::host`.

/// Dense row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl Mat {
    /// Zero-filled `rows x cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Wrap an existing buffer; `data.len()` must equal `rows * cols`.
    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec shape mismatch");
        Mat { data, rows, cols }
    }

    /// Build row-by-row from an iterator of row slices.
    pub fn from_rows<'a>(rows: impl IntoIterator<Item = &'a [f32]>) -> Self {
        let mut data = Vec::new();
        let mut n = 0usize;
        let mut cols = 0usize;
        for r in rows {
            if n == 0 {
                cols = r.len();
            }
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
            n += 1;
        }
        Mat { data, rows: n, cols }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Borrow row `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// New matrix containing the given rows (gather).
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Vec::with_capacity(idx.len() * self.cols);
        for &i in idx {
            out.extend_from_slice(self.row(i));
        }
        Mat::from_vec(out, idx.len(), self.cols)
    }

    /// Vertically stack `self` on top of `other` (same cols).
    pub fn vstack(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "vstack col mismatch");
        let mut data = Vec::with_capacity(self.data.len() + other.data.len());
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Mat::from_vec(data, self.rows + other.rows, self.cols)
    }

    /// Copy with rows of zeros appended until `rows == n` (batch padding).
    pub fn pad_rows_to(&self, n: usize) -> Mat {
        assert!(n >= self.rows, "pad_rows_to shrinks");
        let mut data = self.data.clone();
        data.resize(n * self.cols, 0.0);
        Mat::from_vec(data, n, self.cols)
    }

    /// First `n` rows as a new matrix (batch un-padding).
    pub fn take_rows(&self, n: usize) -> Mat {
        assert!(n <= self.rows, "take_rows grows");
        Mat::from_vec(self.data[..n * self.cols].to_vec(), n, self.cols)
    }

    /// Transposed copy (`[R, C] -> [C, R]`). The host matmul kernels hoist
    /// one of these so their inner loops walk contiguous rows instead of
    /// striding by `cols` (runtime::backend §Perf).
    pub fn transposed(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            let row = self.row(i);
            for (j, &v) in row.iter().enumerate() {
                out.data[j * self.rows + i] = v;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_accessors() {
        let m = Mat::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.get(0, 2), 3.0);
    }

    #[test]
    fn gather_and_stack() {
        let m = Mat::from_vec((0..12).map(|x| x as f32).collect(), 4, 3);
        let g = m.gather_rows(&[3, 0]);
        assert_eq!(g.row(0), &[9.0, 10.0, 11.0]);
        assert_eq!(g.row(1), &[0.0, 1.0, 2.0]);
        let s = g.vstack(&m.gather_rows(&[1]));
        assert_eq!(s.rows(), 3);
        assert_eq!(s.row(2), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn pad_take_roundtrip() {
        let m = Mat::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        let p = m.pad_rows_to(5);
        assert_eq!(p.rows(), 5);
        assert_eq!(p.row(4), &[0.0, 0.0]);
        assert_eq!(p.take_rows(2), m);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn from_vec_checks_shape() {
        Mat::from_vec(vec![1.0; 5], 2, 3);
    }

    #[test]
    fn transposed_roundtrip() {
        let m = Mat::from_vec((0..6).map(|x| x as f32).collect(), 2, 3);
        let t = m.transposed();
        assert_eq!(t.shape(), (3, 2));
        for i in 0..2 {
            for j in 0..3 {
                assert_eq!(t.get(j, i), m.get(i, j));
            }
        }
        assert_eq!(t.transposed(), m);
        // degenerate shapes
        assert_eq!(Mat::zeros(0, 4).transposed().shape(), (4, 0));
    }
}

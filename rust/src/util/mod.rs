//! Shared substrates: everything the offline crate registry forced us to
//! build in-tree (DESIGN.md §Substitutions) plus small data utilities.

pub mod bench;
pub mod chan;
pub mod logger;
pub mod mat;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod topk;

/// FNV-1a over a byte string — the crate's one deterministic string
/// hash (store-latency jitter, cache sharding, property-test case
/// seeds, membership rendezvous weights). Stability matters: several
/// seeded behaviors are pinned by tests, so any change here is a
/// breaking one.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod hash_tests {
    #[test]
    fn fnv1a_matches_the_reference_vectors() {
        // published FNV-1a 64-bit test vectors
        assert_eq!(super::fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(super::fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(super::fnv1a(b"foobar"), 0x85944171f73967e8);
    }
}

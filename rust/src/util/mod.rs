//! Shared substrates: everything the offline crate registry forced us to
//! build in-tree (DESIGN.md §Substitutions) plus small data utilities.

pub mod bench;
pub mod chan;
pub mod logger;
pub mod mat;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod topk;

//! The data cache (paper §3.3): URI -> preprocessed tensor.
//!
//! "Public clouds usually adopt the computation and storage separation
//! design, and transferring the data back and forth ... is very
//! time-consuming" — so once a sample has been downloaded and
//! preprocessed, later AL rounds (and the multi-round PSHEA agent, which
//! re-scans the pool every round) hit this cache instead of the store.
//!
//! Sharded, byte-bounded LRU: keys hash to a shard, each shard keeps exact
//! LRU order; values are `Arc`ed so hits are zero-copy.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A cached, preprocessed sample (f32 image ready for the model).
pub type CachedTensor = Arc<Vec<f32>>;

struct Shard {
    /// key -> (value, lru stamp)
    map: HashMap<String, (CachedTensor, u64)>,
    /// stamp -> key, mirroring `map`'s stamps: the oldest entry is always
    /// the first key, so eviction is O(log n) instead of the old O(n)
    /// min-stamp scan (which went O(n²) under churn).
    lru: BTreeMap<u64, String>,
    /// monotonically increasing use stamp (unique per map entry, so it can
    /// key the BTreeMap)
    tick: u64,
    bytes: usize,
}

impl Shard {
    fn evict_to(&mut self, cap: usize) {
        while self.bytes > cap && !self.map.is_empty() {
            let stamp = *self.lru.keys().next().expect("lru mirrors map");
            let victim = self.lru.remove(&stamp).expect("stamp present");
            if let Some((v, _)) = self.map.remove(&victim) {
                self.bytes -= v.len() * 4;
            }
        }
    }
}

/// Sharded byte-bounded LRU cache.
pub struct DataCache {
    shards: Vec<Mutex<Shard>>,
    capacity_per_shard: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    enabled: bool,
}

impl DataCache {
    /// `capacity_bytes` across `shards` shards. `enabled=false` makes every
    /// lookup a miss (the ablation switch for Table 2 / §Perf).
    pub fn new(capacity_bytes: usize, shards: usize, enabled: bool) -> Self {
        let shards = shards.max(1);
        DataCache {
            shards: (0..shards)
                .map(|_| {
                    Mutex::new(Shard {
                        map: HashMap::new(),
                        lru: BTreeMap::new(),
                        tick: 0,
                        bytes: 0,
                    })
                })
                .collect(),
            capacity_per_shard: capacity_bytes / shards,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            enabled,
        }
    }

    /// From config (capacity in MiB).
    pub fn from_config(cfg: &crate::config::CacheConfig) -> Self {
        Self::new(cfg.capacity_mib * 1024 * 1024, cfg.shards, cfg.enabled)
    }

    fn shard_for(&self, key: &str) -> &Mutex<Shard> {
        let h = crate::util::fnv1a(key.as_bytes());
        &self.shards[(h as usize) % self.shards.len()]
    }

    /// Zero-copy lookup.
    pub fn get(&self, key: &str) -> Option<CachedTensor> {
        if !self.enabled {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let mut shard = self.shard_for(key).lock().unwrap();
        shard.tick += 1;
        let tick = shard.tick;
        let s = &mut *shard;
        let hit = match s.map.get_mut(key) {
            Some((v, stamp)) => {
                // one lookup: refresh the stamp in place and move the lru
                // mirror entry, reusing its stored key String (no alloc)
                let old = std::mem::replace(stamp, tick);
                if let Some(k) = s.lru.remove(&old) {
                    s.lru.insert(tick, k);
                }
                Some(v.clone())
            }
            None => None,
        };
        drop(shard);
        match hit {
            Some(v) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (replaces an existing entry), evicting LRU entries as needed.
    /// Values bigger than a whole shard are not cached.
    pub fn put(&self, key: &str, value: CachedTensor) {
        if !self.enabled {
            return;
        }
        let vbytes = value.len() * 4;
        if vbytes > self.capacity_per_shard {
            return;
        }
        let mut shard = self.shard_for(key).lock().unwrap();
        shard.tick += 1;
        let tick = shard.tick;
        if let Some((old, old_stamp)) = shard.map.insert(key.to_string(), (value, tick)) {
            shard.bytes -= old.len() * 4;
            shard.lru.remove(&old_stamp);
        }
        shard.lru.insert(tick, key.to_string());
        shard.bytes += vbytes;
        let cap = self.capacity_per_shard;
        shard.evict_to(cap);
    }

    /// Fetch-through: `get` or compute-and-`put`.
    pub fn get_or_insert_with<E>(
        &self,
        key: &str,
        f: impl FnOnce() -> Result<Vec<f32>, E>,
    ) -> Result<CachedTensor, E> {
        if let Some(v) = self.get(key) {
            return Ok(v);
        }
        let v = Arc::new(f()?);
        self.put(key, v.clone());
        Ok(v)
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Total cached bytes across shards (racy; metrics only).
    pub fn bytes(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().bytes).sum()
    }

    /// Total entries across shards (racy; metrics only).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;

    fn tensor(n: usize, fill: f32) -> CachedTensor {
        Arc::new(vec![fill; n])
    }

    #[test]
    fn get_after_put() {
        let c = DataCache::new(1024, 2, true);
        c.put("a", tensor(10, 1.0));
        assert_eq!(c.get("a").unwrap()[0], 1.0);
        assert_eq!(c.hits(), 1);
        assert!(c.get("b").is_none());
        assert_eq!(c.misses(), 1);
    }

    #[test]
    fn disabled_cache_never_hits() {
        let c = DataCache::new(1024, 2, false);
        c.put("a", tensor(10, 1.0));
        assert!(c.get("a").is_none());
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn evicts_lru_not_mru() {
        // single shard, capacity = 3 tensors of 10 floats
        let c = DataCache::new(120, 1, true);
        c.put("a", tensor(10, 1.0));
        c.put("b", tensor(10, 2.0));
        c.put("c", tensor(10, 3.0));
        c.get("a"); // refresh a
        c.put("d", tensor(10, 4.0)); // evicts b (lru)
        assert!(c.get("a").is_some());
        assert!(c.get("b").is_none(), "b should be evicted");
        assert!(c.get("c").is_some());
        assert!(c.get("d").is_some());
    }

    #[test]
    fn replace_updates_bytes() {
        let c = DataCache::new(120, 1, true);
        c.put("a", tensor(10, 1.0));
        c.put("a", tensor(20, 2.0));
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 80);
        assert_eq!(c.get("a").unwrap().len(), 20);
    }

    #[test]
    fn oversized_value_not_cached() {
        let c = DataCache::new(100, 1, true);
        c.put("big", tensor(1000, 1.0));
        assert!(c.get("big").is_none());
        assert_eq!(c.bytes(), 0);
    }

    #[test]
    fn get_or_insert_with_computes_once() {
        let c = DataCache::new(1024, 1, true);
        let mut calls = 0;
        let v: Result<_, ()> = c.get_or_insert_with("k", || {
            calls += 1;
            Ok(vec![7.0])
        });
        assert_eq!(v.unwrap()[0], 7.0);
        let _: Result<_, ()> = c.get_or_insert_with("k", || {
            calls += 1;
            Ok(vec![8.0])
        });
        assert_eq!(calls, 1, "second call must hit");
    }

    #[test]
    fn error_passthrough_does_not_cache() {
        let c = DataCache::new(1024, 1, true);
        let r: Result<CachedTensor, String> = c.get_or_insert_with("k", || Err("boom".into()));
        assert!(r.is_err());
        assert!(c.get("k").is_none());
    }

    /// The O(log n) eviction index must preserve exact LRU order under
    /// interleaved get/put churn — checked against a brute-force model
    /// that replays the same operations and evicts by scanning stamps.
    #[test]
    fn prop_lru_order_preserved_under_churn() {
        crate::util::prop::check("cache-lru-order", 40, |rng| {
            let slots = 3 + rng.below(6); // capacity in 10-float tensors
            let c = DataCache::new(slots * 40, 1, true);
            // model: Vec of (key, stamp); eviction removes min stamp
            let mut model: Vec<(String, u64)> = Vec::new();
            let mut tick = 0u64;
            for _ in 0..300 {
                let key = format!("k{}", rng.below(12));
                tick += 1;
                if rng.below(3) == 0 {
                    let hit = c.get(&key).is_some();
                    let model_hit = model.iter().any(|(k, _)| *k == key);
                    prop_assert!(hit == model_hit, "get('{key}') hit mismatch");
                    if let Some(e) = model.iter_mut().find(|(k, _)| *k == key) {
                        e.1 = tick;
                    }
                } else {
                    c.put(&key, tensor(10, 1.0));
                    model.retain(|(k, _)| *k != key);
                    model.push((key, tick));
                    while model.len() > slots {
                        let oldest = model
                            .iter()
                            .enumerate()
                            .min_by_key(|(_, (_, s))| *s)
                            .map(|(i, _)| i)
                            .unwrap();
                        model.remove(oldest);
                    }
                }
            }
            prop_assert!(
                c.len() == model.len(),
                "cache holds {} entries, model {}",
                c.len(),
                model.len()
            );
            for (k, _) in &model {
                prop_assert!(c.get(k).is_some(), "model key '{k}' missing from cache");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_never_exceeds_capacity() {
        crate::util::prop::check("cache-capacity", 50, |rng| {
            let cap = 200 + rng.below(2000);
            let shards = 1 + rng.below(4);
            let c = DataCache::new(cap, shards, true);
            for i in 0..200 {
                let n = 1 + rng.below(30);
                c.put(&format!("k{}", i % 60), tensor(n, i as f32));
                prop_assert!(
                    c.bytes() <= cap,
                    "cache bytes {} exceed capacity {cap}",
                    c.bytes()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn concurrent_put_get() {
        let c = Arc::new(DataCache::new(100_000, 8, true));
        std::thread::scope(|s| {
            for t in 0..8 {
                let c = c.clone();
                s.spawn(move || {
                    for i in 0..500 {
                        let key = format!("t{t}-{}", i % 50);
                        if i % 3 == 0 {
                            c.put(&key, tensor(16, i as f32));
                        } else {
                            let _ = c.get(&key);
                        }
                    }
                });
            }
        });
        assert!(c.bytes() <= 100_000);
    }
}

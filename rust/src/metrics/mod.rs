//! Metrics substrate: counters, log-bucketed latency histograms, throughput
//! meters, and a JSON snapshot the server exposes over RPC.
//!
//! The paper's efficiency claims (Table 2, Fig 4b/4c) are latency and
//! throughput numbers; every pipeline stage and the end-to-end path record
//! into one shared `Registry` so the bench harness and the `metrics` RPC
//! read the same source of truth.
//!
//! Well-known families beyond the pipeline stages: `pool.*` (connection
//! reuse: dials, hits, evictions, retries) and `mux.*` for the
//! multiplexed wire — `mux.in_flight` (gauge: requests parked on shared
//! connections), `mux.frames` (counter: reply frames demultiplexed), and
//! `mux.head_of_line_ms` (histogram: how long a routed reply waited for
//! its requester to pick it up — the head-of-line signal).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::json::{Map, Value};

/// Log-bucketed latency histogram: 4 linear sub-buckets per power of two,
/// nanosecond resolution, fixed footprint (256 buckets covers ns..>1h).
/// Records are lock-free (atomic adds).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

const SUB_BITS: u32 = 2; // 4 sub-buckets per octave
const NUM_BUCKETS: usize = 256;

fn bucket_index(ns: u64) -> usize {
    if ns == 0 {
        return 0;
    }
    let msb = 63 - ns.leading_zeros();
    let idx = if msb <= SUB_BITS {
        ns as usize
    } else {
        let sub = ((ns >> (msb - SUB_BITS)) & ((1 << SUB_BITS) - 1)) as usize;
        (((msb - SUB_BITS) as usize) << SUB_BITS | sub) + (1 << SUB_BITS)
    };
    idx.min(NUM_BUCKETS - 1)
}

/// Representative (upper-bound) value of a bucket, used for percentiles.
fn bucket_value(idx: usize) -> u64 {
    if idx < (1 << SUB_BITS) {
        return idx as u64;
    }
    let idx = idx - (1 << SUB_BITS);
    let msb = (idx >> SUB_BITS) as u32 + SUB_BITS;
    let sub = (idx & ((1 << SUB_BITS) - 1)) as u128;
    let v = (1u128 << msb) + ((sub + 1) << (msb - SUB_BITS)) - 1;
    v.min(u64::MAX as u128) as u64
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed) / c)
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns.load(Ordering::Relaxed))
    }

    /// Approximate percentile (upper bucket bound), p in [0, 1].
    pub fn percentile(&self, p: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((total as f64) * p).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_nanos(bucket_value(i));
            }
        }
        self.max()
    }

    fn snapshot(&self) -> Value {
        let mut m = Map::new();
        m.insert("count", Value::from(self.count()));
        m.insert("mean_us", Value::Number(self.mean().as_secs_f64() * 1e6));
        m.insert("p50_us", Value::Number(self.percentile(0.50).as_secs_f64() * 1e6));
        m.insert("p95_us", Value::Number(self.percentile(0.95).as_secs_f64() * 1e6));
        m.insert("p99_us", Value::Number(self.percentile(0.99).as_secs_f64() * 1e6));
        m.insert("max_us", Value::Number(self.max().as_secs_f64() * 1e6));
        Value::Object(m)
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Seconds of trailing history a meter keeps for its windowed rate.
const WIN_SECS: usize = 60;

/// One-second window bucket: `sec` is the absolute second (since the
/// meter started) the bucket currently belongs to, `n` its event count.
struct WinBucket {
    sec: AtomicU64,
    n: AtomicU64,
}

/// Throughput meter. `count()` is monotonic over the meter's lifetime;
/// `rate_per_sec()` is the lifetime average (useful for batch runs) and
/// `rate_1m()` the trailing-60s rate (what a long-lived server is doing
/// *now*, per-second bucketed). Recording stays lock-free; a bucket
/// rollover race can drop a blip from the window, never from `count()`.
pub struct Meter {
    count: AtomicU64,
    started: Instant,
    window: Vec<WinBucket>,
}

impl Meter {
    pub fn new() -> Self {
        Meter {
            count: AtomicU64::new(0),
            started: Instant::now(),
            window: (0..WIN_SECS)
                .map(|_| WinBucket { sec: AtomicU64::new(u64::MAX), n: AtomicU64::new(0) })
                .collect(),
        }
    }

    pub fn add(&self, n: u64) {
        self.count.fetch_add(n, Ordering::Relaxed);
        self.add_window(self.started.elapsed().as_secs(), n);
    }

    fn add_window(&self, now_sec: u64, n: u64) {
        let b = &self.window[(now_sec % WIN_SECS as u64) as usize];
        let cur = b.sec.load(Ordering::Acquire);
        if cur != now_sec
            && b.sec
                .compare_exchange(cur, now_sec, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
        {
            // the CAS winner retires the bucket's previous second
            b.n.store(0, Ordering::Release);
        }
        b.n.fetch_add(n, Ordering::Relaxed);
    }

    fn window_total(&self, now_sec: u64) -> u64 {
        self.window
            .iter()
            .filter(|b| {
                let sec = b.sec.load(Ordering::Acquire);
                sec != u64::MAX && now_sec.saturating_sub(sec) < WIN_SECS as u64
            })
            .map(|b| b.n.load(Ordering::Relaxed))
            .sum()
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Lifetime-average items/sec.
    pub fn rate_per_sec(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.count() as f64 / secs
    }

    /// Items/sec over the trailing 60s window (falls back to the
    /// lifetime span while the meter is younger than the window).
    pub fn rate_1m(&self) -> f64 {
        let elapsed = self.started.elapsed();
        let span = elapsed.as_secs_f64().min(WIN_SECS as f64);
        if span <= 0.0 {
            return 0.0;
        }
        self.window_total(elapsed.as_secs()) as f64 / span
    }
}

impl Default for Meter {
    fn default() -> Self {
        Self::new()
    }
}

/// Named metrics registry shared across the server.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
    meters: Mutex<BTreeMap<String, Arc<Meter>>>,
}

impl Registry {
    pub fn new() -> Arc<Self> {
        Arc::new(Registry::default())
    }

    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(AtomicU64::new(0)))
            .clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    pub fn meter(&self, name: &str) -> Arc<Meter> {
        self.meters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Meter::new()))
            .clone()
    }

    /// Record a duration under `name` (creates the histogram on first use).
    pub fn time(&self, name: &str, d: Duration) {
        self.histogram(name).record(d);
    }

    /// Set a counter-backed gauge to an absolute value (membership view
    /// generation, live-worker count, straggler spread): last write
    /// wins, unlike the monotonic `fetch_add` counters.
    pub fn gauge_set(&self, name: &str, v: u64) {
        self.counter(name).store(v, Ordering::Relaxed);
    }

    /// Full JSON snapshot (served by the `metrics` RPC).
    pub fn snapshot(&self) -> Value {
        let mut root = Map::new();
        let mut counters = Map::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            counters.insert(k.clone(), Value::from(v.load(Ordering::Relaxed)));
        }
        root.insert("counters", Value::Object(counters));
        let mut hists = Map::new();
        for (k, h) in self.histograms.lock().unwrap().iter() {
            hists.insert(k.clone(), h.snapshot());
        }
        root.insert("histograms", Value::Object(hists));
        let mut meters = Map::new();
        for (k, m) in self.meters.lock().unwrap().iter() {
            let mut mm = Map::new();
            mm.insert("count", Value::from(m.count()));
            mm.insert("rate_per_sec", Value::Number(m.rate_per_sec()));
            mm.insert("rate_1m", Value::Number(m.rate_1m()));
            meters.insert(k.clone(), Value::Object(mm));
        }
        root.insert("meters", Value::Object(meters));
        Value::Object(root)
    }
}

/// Render a [`Registry::snapshot`] in the Prometheus text exposition
/// format (`name{quantile="0.99"} value`), served by the `metrics_text`
/// RPC so the service is scrapeable without custom tooling. Pure over
/// the snapshot JSON, so a golden test can pin the exact output.
pub fn render_prometheus(snapshot: &Value) -> String {
    fn sanitize(name: &str) -> String {
        name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect()
    }
    fn num(v: Option<&Value>) -> String {
        let f = v.and_then(Value::as_f64).unwrap_or(0.0);
        if f.fract() == 0.0 && f.abs() < 1e15 {
            format!("{}", f as i64)
        } else {
            format!("{f}")
        }
    }
    let mut out = String::new();
    if let Some(counters) = snapshot.get("counters").and_then(Value::as_object) {
        for (k, v) in counters.iter() {
            out.push_str(&format!("alaas_{} {}\n", sanitize(k), num(Some(v))));
        }
    }
    if let Some(hists) = snapshot.get("histograms").and_then(Value::as_object) {
        for (k, h) in hists.iter() {
            let name = sanitize(k);
            out.push_str(&format!("alaas_{name}_count {}\n", num(h.get("count"))));
            for (q, field) in [("0.5", "p50_us"), ("0.95", "p95_us"), ("0.99", "p99_us")] {
                out.push_str(&format!(
                    "alaas_{name}_us{{quantile=\"{q}\"}} {}\n",
                    num(h.get(field))
                ));
            }
            out.push_str(&format!("alaas_{name}_mean_us {}\n", num(h.get("mean_us"))));
            out.push_str(&format!("alaas_{name}_max_us {}\n", num(h.get("max_us"))));
        }
    }
    if let Some(meters) = snapshot.get("meters").and_then(Value::as_object) {
        for (k, m) in meters.iter() {
            let name = sanitize(k);
            out.push_str(&format!("alaas_{name}_total {}\n", num(m.get("count"))));
            out.push_str(&format!(
                "alaas_{name}_rate_per_sec {}\n",
                num(m.get("rate_per_sec"))
            ));
            out.push_str(&format!("alaas_{name}_rate_1m {}\n", num(m.get("rate_1m"))));
        }
    }
    out
}

/// RAII timer recording into a histogram on drop.
pub struct Timed {
    hist: Arc<Histogram>,
    start: Instant,
}

impl Timed {
    pub fn new(hist: Arc<Histogram>) -> Self {
        Timed { hist, start: Instant::now() }
    }
}

impl Drop for Timed {
    fn drop(&mut self) {
        self.hist.record(self.start.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_monotonic_and_bounded() {
        let mut prev = 0;
        for ns in [0u64, 1, 2, 3, 4, 7, 8, 100, 1000, 1_000_000, u64::MAX / 2] {
            let b = bucket_index(ns);
            assert!(b >= prev || ns < 4, "bucket not monotonic at {ns}");
            assert!(b < NUM_BUCKETS);
            prev = b;
        }
    }

    #[test]
    fn bucket_value_bounds_its_range() {
        // Every recorded ns must be <= the representative value of its
        // bucket (so percentiles are conservative upper bounds).
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..1000 {
            let ns = rng.next_u64() >> (rng.below(40) as u32);
            let idx = bucket_index(ns);
            if idx < NUM_BUCKETS - 1 {
                assert!(
                    bucket_value(idx) >= ns,
                    "bucket_value({idx})={} < ns={ns}",
                    bucket_value(idx)
                );
            }
        }
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::new();
        for ms in [1u64, 2, 3, 4, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_millis(20));
        assert!(h.percentile(0.5) >= Duration::from_millis(2));
        assert!(h.percentile(0.5) <= Duration::from_millis(5));
        assert!(h.percentile(1.0) >= Duration::from_millis(100));
        // approximate: within a bucket width
        assert!(h.percentile(1.0) <= Duration::from_millis(130));
    }

    #[test]
    fn percentile_accuracy_within_bucket_width() {
        let h = Histogram::new();
        let mut rng = crate::util::rng::Rng::new(3);
        let mut all: Vec<u64> = vec![];
        for _ in 0..10_000 {
            let us = 50 + rng.below(10_000) as u64;
            all.push(us * 1000);
            h.record(Duration::from_micros(us));
        }
        all.sort_unstable();
        let exact = all[(all.len() as f64 * 0.95) as usize] as f64;
        let approx = h.percentile(0.95).as_nanos() as f64;
        // log-bucket relative error is bounded by 1/2^SUB_BITS = 25%
        assert!((approx - exact).abs() / exact < 0.25, "approx={approx} exact={exact}");
    }

    #[test]
    fn registry_snapshot_shape() {
        let r = Registry::new();
        r.counter("cache.hits").fetch_add(3, Ordering::Relaxed);
        r.time("stage.fetch", Duration::from_micros(120));
        r.meter("e2e.images").add(42);
        let snap = r.snapshot();
        assert_eq!(snap.path("counters.cache\u{2e}hits").is_some(), false); // dots are literal keys
        assert_eq!(
            snap.get("counters").unwrap().get("cache.hits").unwrap().as_i64(),
            Some(3)
        );
        assert!(snap.get("histograms").unwrap().get("stage.fetch").unwrap().get("p50_us").is_some());
        assert_eq!(
            snap.get("meters").unwrap().get("e2e.images").unwrap().get("count").unwrap().as_i64(),
            Some(42)
        );
    }

    #[test]
    fn gauge_set_overwrites_instead_of_accumulating() {
        let r = Registry::new();
        r.gauge_set("membership.generation", 3);
        r.gauge_set("membership.generation", 7);
        assert_eq!(r.counter("membership.generation").load(Ordering::Relaxed), 7);
        let snap = r.snapshot();
        assert_eq!(
            snap.get("counters").unwrap().get("membership.generation").unwrap().as_i64(),
            Some(7)
        );
    }

    #[test]
    fn meter_window_tracks_current_rate_not_history() {
        let m = Meter::new();
        // synthetic clock: 5 events in the first second, then silence
        // until second 120, then 12 events
        m.add_window(0, 5);
        assert_eq!(m.window_total(0), 5);
        assert_eq!(m.window_total(59), 5, "still inside the 60s window");
        assert_eq!(m.window_total(60), 0, "aged out");
        m.add_window(120, 12);
        // second 120 reuses bucket index 0; the old second-0 count is gone
        assert_eq!(m.window_total(120), 12);
        // adjacent seconds accumulate into distinct buckets
        m.add_window(121, 3);
        assert_eq!(m.window_total(121), 15);
    }

    #[test]
    fn meter_count_stays_monotonic_and_rates_are_sane() {
        let m = Meter::new();
        m.add(10);
        m.add(5);
        assert_eq!(m.count(), 15);
        assert!(m.rate_per_sec() > 0.0);
        assert!(m.rate_1m() > 0.0);
        let snap = {
            let r = Registry::new();
            r.meter("x").add(7);
            r.snapshot()
        };
        let x = snap.get("meters").unwrap().get("x").unwrap();
        assert_eq!(x.get("count").unwrap().as_i64(), Some(7));
        assert!(x.get("rate_per_sec").is_some());
        assert!(x.get("rate_1m").is_some());
    }

    #[test]
    fn prometheus_rendering_matches_golden_snapshot() {
        // hand-built snapshot so every value (incl. rates) is fixed
        use crate::json::value::obj;
        let snap = obj([
            (
                "counters",
                obj([
                    ("cache.hits", Value::from(3u64)),
                    // durability plane (DESIGN.md §Durability)
                    ("recovery.replayed_records", Value::from(17u64)),
                    ("recovery.resumed_jobs", Value::from(1u64)),
                    ("rpc.errors", Value::from(0u64)),
                    ("wal.appends", Value::from(9u64)),
                    ("wal.bytes", Value::from(2048u64)),
                ]),
            ),
            (
                "histograms",
                obj([
                    (
                        "pool.backoff_ms",
                        obj([
                            ("count", Value::from(2u64)),
                            ("mean_us", Value::Number(15000.0)),
                            ("p50_us", Value::Number(10000.0)),
                            ("p95_us", Value::Number(20000.0)),
                            ("p99_us", Value::Number(20000.0)),
                            ("max_us", Value::Number(20000.0)),
                        ]),
                    ),
                    (
                        "rpc.query",
                        obj([
                            ("count", Value::from(4u64)),
                            ("mean_us", Value::Number(250.0)),
                            ("p50_us", Value::Number(200.0)),
                            ("p95_us", Value::Number(400.0)),
                            ("p99_us", Value::Number(400.0)),
                            ("max_us", Value::Number(412.5)),
                        ]),
                    ),
                    (
                        "wal.fsync_ms",
                        obj([
                            ("count", Value::from(9u64)),
                            ("mean_us", Value::Number(800.0)),
                            ("p50_us", Value::Number(500.0)),
                            ("p95_us", Value::Number(2000.0)),
                            ("p99_us", Value::Number(2000.0)),
                            ("max_us", Value::Number(2500.0)),
                        ]),
                    ),
                ]),
            ),
            (
                "meters",
                obj([(
                    "pipeline.samples",
                    obj([
                        ("count", Value::from(42u64)),
                        ("rate_per_sec", Value::Number(1.5)),
                        ("rate_1m", Value::Number(6.0)),
                    ]),
                )]),
            ),
        ]);
        let golden = "\
alaas_cache_hits 3\n\
alaas_recovery_replayed_records 17\n\
alaas_recovery_resumed_jobs 1\n\
alaas_rpc_errors 0\n\
alaas_wal_appends 9\n\
alaas_wal_bytes 2048\n\
alaas_pool_backoff_ms_count 2\n\
alaas_pool_backoff_ms_us{quantile=\"0.5\"} 10000\n\
alaas_pool_backoff_ms_us{quantile=\"0.95\"} 20000\n\
alaas_pool_backoff_ms_us{quantile=\"0.99\"} 20000\n\
alaas_pool_backoff_ms_mean_us 15000\n\
alaas_pool_backoff_ms_max_us 20000\n\
alaas_rpc_query_count 4\n\
alaas_rpc_query_us{quantile=\"0.5\"} 200\n\
alaas_rpc_query_us{quantile=\"0.95\"} 400\n\
alaas_rpc_query_us{quantile=\"0.99\"} 400\n\
alaas_rpc_query_mean_us 250\n\
alaas_rpc_query_max_us 412.5\n\
alaas_wal_fsync_ms_count 9\n\
alaas_wal_fsync_ms_us{quantile=\"0.5\"} 500\n\
alaas_wal_fsync_ms_us{quantile=\"0.95\"} 2000\n\
alaas_wal_fsync_ms_us{quantile=\"0.99\"} 2000\n\
alaas_wal_fsync_ms_mean_us 800\n\
alaas_wal_fsync_ms_max_us 2500\n\
alaas_pipeline_samples_total 42\n\
alaas_pipeline_samples_rate_per_sec 1.5\n\
alaas_pipeline_samples_rate_1m 6\n";
        assert_eq!(render_prometheus(&snap), golden);
    }

    #[test]
    fn prometheus_rendering_of_live_registry_is_parseable() {
        let r = Registry::new();
        r.counter("cache.hits").fetch_add(3, Ordering::Relaxed);
        r.time("stage.fetch", Duration::from_micros(120));
        r.meter("e2e.images").add(42);
        let text = render_prometheus(&r.snapshot());
        for line in text.lines() {
            let (name, value) = line.rsplit_once(' ').expect("name value");
            assert!(name.starts_with("alaas_"), "{line}");
            assert!(value.parse::<f64>().is_ok(), "{line}");
        }
        assert!(text.contains("alaas_cache_hits 3\n"));
        assert!(text.contains("alaas_stage_fetch_us{quantile=\"0.95\"}"));
        assert!(text.contains("alaas_e2e_images_total 42\n"));
    }

    #[test]
    fn timed_records_on_drop() {
        let r = Registry::new();
        {
            let _t = Timed::new(r.histogram("x"));
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(r.histogram("x").count(), 1);
        assert!(r.histogram("x").mean() >= Duration::from_millis(1));
    }

    #[test]
    fn concurrent_recording() {
        let h = Arc::new(Histogram::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..1000 {
                        h.record(Duration::from_nanos(i));
                    }
                });
            }
        });
        assert_eq!(h.count(), 8000);
    }
}

//! Metrics substrate: counters, log-bucketed latency histograms, throughput
//! meters, and a JSON snapshot the server exposes over RPC.
//!
//! The paper's efficiency claims (Table 2, Fig 4b/4c) are latency and
//! throughput numbers; every pipeline stage and the end-to-end path record
//! into one shared `Registry` so the bench harness and the `metrics` RPC
//! read the same source of truth.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::json::{Map, Value};

/// Log-bucketed latency histogram: 4 linear sub-buckets per power of two,
/// nanosecond resolution, fixed footprint (256 buckets covers ns..>1h).
/// Records are lock-free (atomic adds).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

const SUB_BITS: u32 = 2; // 4 sub-buckets per octave
const NUM_BUCKETS: usize = 256;

fn bucket_index(ns: u64) -> usize {
    if ns == 0 {
        return 0;
    }
    let msb = 63 - ns.leading_zeros();
    let idx = if msb <= SUB_BITS {
        ns as usize
    } else {
        let sub = ((ns >> (msb - SUB_BITS)) & ((1 << SUB_BITS) - 1)) as usize;
        (((msb - SUB_BITS) as usize) << SUB_BITS | sub) + (1 << SUB_BITS)
    };
    idx.min(NUM_BUCKETS - 1)
}

/// Representative (upper-bound) value of a bucket, used for percentiles.
fn bucket_value(idx: usize) -> u64 {
    if idx < (1 << SUB_BITS) {
        return idx as u64;
    }
    let idx = idx - (1 << SUB_BITS);
    let msb = (idx >> SUB_BITS) as u32 + SUB_BITS;
    let sub = (idx & ((1 << SUB_BITS) - 1)) as u128;
    let v = (1u128 << msb) + ((sub + 1) << (msb - SUB_BITS)) - 1;
    v.min(u64::MAX as u128) as u64
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed) / c)
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns.load(Ordering::Relaxed))
    }

    /// Approximate percentile (upper bucket bound), p in [0, 1].
    pub fn percentile(&self, p: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((total as f64) * p).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_nanos(bucket_value(i));
            }
        }
        self.max()
    }

    fn snapshot(&self) -> Value {
        let mut m = Map::new();
        m.insert("count", Value::from(self.count()));
        m.insert("mean_us", Value::Number(self.mean().as_secs_f64() * 1e6));
        m.insert("p50_us", Value::Number(self.percentile(0.50).as_secs_f64() * 1e6));
        m.insert("p95_us", Value::Number(self.percentile(0.95).as_secs_f64() * 1e6));
        m.insert("p99_us", Value::Number(self.percentile(0.99).as_secs_f64() * 1e6));
        m.insert("max_us", Value::Number(self.max().as_secs_f64() * 1e6));
        Value::Object(m)
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Items/sec over the meter's lifetime.
pub struct Meter {
    count: AtomicU64,
    started: Instant,
}

impl Meter {
    pub fn new() -> Self {
        Meter { count: AtomicU64::new(0), started: Instant::now() }
    }

    pub fn add(&self, n: u64) {
        self.count.fetch_add(n, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn rate_per_sec(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.count() as f64 / secs
    }
}

impl Default for Meter {
    fn default() -> Self {
        Self::new()
    }
}

/// Named metrics registry shared across the server.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
    meters: Mutex<BTreeMap<String, Arc<Meter>>>,
}

impl Registry {
    pub fn new() -> Arc<Self> {
        Arc::new(Registry::default())
    }

    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(AtomicU64::new(0)))
            .clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    pub fn meter(&self, name: &str) -> Arc<Meter> {
        self.meters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Meter::new()))
            .clone()
    }

    /// Record a duration under `name` (creates the histogram on first use).
    pub fn time(&self, name: &str, d: Duration) {
        self.histogram(name).record(d);
    }

    /// Set a counter-backed gauge to an absolute value (membership view
    /// generation, live-worker count, straggler spread): last write
    /// wins, unlike the monotonic `fetch_add` counters.
    pub fn gauge_set(&self, name: &str, v: u64) {
        self.counter(name).store(v, Ordering::Relaxed);
    }

    /// Full JSON snapshot (served by the `metrics` RPC).
    pub fn snapshot(&self) -> Value {
        let mut root = Map::new();
        let mut counters = Map::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            counters.insert(k.clone(), Value::from(v.load(Ordering::Relaxed)));
        }
        root.insert("counters", Value::Object(counters));
        let mut hists = Map::new();
        for (k, h) in self.histograms.lock().unwrap().iter() {
            hists.insert(k.clone(), h.snapshot());
        }
        root.insert("histograms", Value::Object(hists));
        let mut meters = Map::new();
        for (k, m) in self.meters.lock().unwrap().iter() {
            let mut mm = Map::new();
            mm.insert("count", Value::from(m.count()));
            mm.insert("rate_per_sec", Value::Number(m.rate_per_sec()));
            meters.insert(k.clone(), Value::Object(mm));
        }
        root.insert("meters", Value::Object(meters));
        Value::Object(root)
    }
}

/// RAII timer recording into a histogram on drop.
pub struct Timed {
    hist: Arc<Histogram>,
    start: Instant,
}

impl Timed {
    pub fn new(hist: Arc<Histogram>) -> Self {
        Timed { hist, start: Instant::now() }
    }
}

impl Drop for Timed {
    fn drop(&mut self) {
        self.hist.record(self.start.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_monotonic_and_bounded() {
        let mut prev = 0;
        for ns in [0u64, 1, 2, 3, 4, 7, 8, 100, 1000, 1_000_000, u64::MAX / 2] {
            let b = bucket_index(ns);
            assert!(b >= prev || ns < 4, "bucket not monotonic at {ns}");
            assert!(b < NUM_BUCKETS);
            prev = b;
        }
    }

    #[test]
    fn bucket_value_bounds_its_range() {
        // Every recorded ns must be <= the representative value of its
        // bucket (so percentiles are conservative upper bounds).
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..1000 {
            let ns = rng.next_u64() >> (rng.below(40) as u32);
            let idx = bucket_index(ns);
            if idx < NUM_BUCKETS - 1 {
                assert!(
                    bucket_value(idx) >= ns,
                    "bucket_value({idx})={} < ns={ns}",
                    bucket_value(idx)
                );
            }
        }
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::new();
        for ms in [1u64, 2, 3, 4, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_millis(20));
        assert!(h.percentile(0.5) >= Duration::from_millis(2));
        assert!(h.percentile(0.5) <= Duration::from_millis(5));
        assert!(h.percentile(1.0) >= Duration::from_millis(100));
        // approximate: within a bucket width
        assert!(h.percentile(1.0) <= Duration::from_millis(130));
    }

    #[test]
    fn percentile_accuracy_within_bucket_width() {
        let h = Histogram::new();
        let mut rng = crate::util::rng::Rng::new(3);
        let mut all: Vec<u64> = vec![];
        for _ in 0..10_000 {
            let us = 50 + rng.below(10_000) as u64;
            all.push(us * 1000);
            h.record(Duration::from_micros(us));
        }
        all.sort_unstable();
        let exact = all[(all.len() as f64 * 0.95) as usize] as f64;
        let approx = h.percentile(0.95).as_nanos() as f64;
        // log-bucket relative error is bounded by 1/2^SUB_BITS = 25%
        assert!((approx - exact).abs() / exact < 0.25, "approx={approx} exact={exact}");
    }

    #[test]
    fn registry_snapshot_shape() {
        let r = Registry::new();
        r.counter("cache.hits").fetch_add(3, Ordering::Relaxed);
        r.time("stage.fetch", Duration::from_micros(120));
        r.meter("e2e.images").add(42);
        let snap = r.snapshot();
        assert_eq!(snap.path("counters.cache\u{2e}hits").is_some(), false); // dots are literal keys
        assert_eq!(
            snap.get("counters").unwrap().get("cache.hits").unwrap().as_i64(),
            Some(3)
        );
        assert!(snap.get("histograms").unwrap().get("stage.fetch").unwrap().get("p50_us").is_some());
        assert_eq!(
            snap.get("meters").unwrap().get("e2e.images").unwrap().get("count").unwrap().as_i64(),
            Some(42)
        );
    }

    #[test]
    fn gauge_set_overwrites_instead_of_accumulating() {
        let r = Registry::new();
        r.gauge_set("membership.generation", 3);
        r.gauge_set("membership.generation", 7);
        assert_eq!(r.counter("membership.generation").load(Ordering::Relaxed), 7);
        let snap = r.snapshot();
        assert_eq!(
            snap.get("counters").unwrap().get("membership.generation").unwrap().as_i64(),
            Some(7)
        );
    }

    #[test]
    fn timed_records_on_drop() {
        let r = Registry::new();
        {
            let _t = Timed::new(r.histogram("x"));
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(r.histogram("x").count(), 1);
        assert!(r.histogram("x").mean() >= Duration::from_millis(1));
    }

    #[test]
    fn concurrent_recording() {
        let h = Arc::new(Histogram::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..1000 {
                        h.record(Duration::from_nanos(i));
                    }
                });
            }
        });
        assert_eq!(h.count(), 8000);
    }
}

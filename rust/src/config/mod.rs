//! Configuration-as-a-service (paper §3.2, Figure 2).
//!
//! One YAML file fully describes an AL service: model + batching, strategy
//! (a named one, or `auto` to engage the PSHEA agent), worker topology,
//! store simulation and cache parameters. `AlaasConfig::from_yaml_str`
//! validates everything up front so a bad config fails at start, not
//! mid-run. Every field has a default matching the paper's experimental
//! setup, so the quickstart config is a handful of lines (Fig 2).

use crate::cluster::membership::MembershipConfig;
use crate::durable::{DurabilityConfig, FsyncPolicy};
use crate::json::Value;
use crate::server::pool::PoolConfig;
use crate::server::wire::WireMode;
use crate::yamlmini;

/// Validation failure: which field, what's wrong.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
#[error("config error at '{field}': {reason}")]
pub struct ConfigError {
    pub field: String,
    pub reason: String,
}

fn cerr(field: &str, reason: impl Into<String>) -> ConfigError {
    ConfigError { field: field.to_string(), reason: reason.into() }
}

/// Strategy selection: a named zoo entry or automatic (PSHEA agent).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StrategyChoice {
    Auto,
    Named(String),
}

impl StrategyChoice {
    pub fn as_str(&self) -> &str {
        match self {
            StrategyChoice::Auto => "auto",
            StrategyChoice::Named(s) => s,
        }
    }
}

/// `active_learning.model.*`
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Informational model name (the artifact set is fixed by `make
    /// artifacts`; paper: "resnet18").
    pub name: String,
    /// Informational hub tag (paper: torchvision release).
    pub hub_name: String,
    /// Inference batch size for the serving path (Fig 4c sweeps this).
    pub batch_size: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            name: "resnet18-sim".into(),
            hub_name: "alaas/fixed-seed-trunk".into(),
            batch_size: 16,
        }
    }
}

/// `active_learning.*`
#[derive(Debug, Clone, PartialEq)]
pub struct ActiveLearningConfig {
    pub strategy: StrategyChoice,
    pub model: ModelConfig,
    /// Serving device (only `CPU` is wired in this environment).
    pub device: String,
    /// PSHEA knobs (used when strategy = auto).
    pub agent: AgentConfig,
}

impl Default for ActiveLearningConfig {
    fn default() -> Self {
        ActiveLearningConfig {
            strategy: StrategyChoice::Named("least_confidence".into()),
            model: ModelConfig::default(),
            device: "CPU".into(),
            agent: AgentConfig::default(),
        }
    }
}

/// PSHEA agent knobs (Algorithm 1 inputs; the full `PsheaConfig` surface,
/// with identical defaults). These are the per-server defaults the
/// `agent_start` RPC starts from — a request may override any field.
#[derive(Debug, Clone, PartialEq)]
pub struct AgentConfig {
    /// Target accuracy `a_t` (stop when reached).
    pub target_accuracy: f64,
    /// Maximum labeling budget `b_max` (samples).
    pub max_budget: usize,
    /// Budget spent per strategy per round (samples).
    pub round_budget: usize,
    /// Rounds with < `converge_eps` improvement that count as converged.
    pub converge_rounds: usize,
    pub converge_eps: f64,
    /// Hard cap on rounds (0 = unlimited).
    pub max_rounds: usize,
    /// Observations each arm needs before elimination starts.
    pub min_history: usize,
}

impl Default for AgentConfig {
    fn default() -> Self {
        AgentConfig {
            target_accuracy: 0.95,
            max_budget: 10_000,
            round_budget: 500,
            converge_rounds: 3,
            converge_eps: 0.002,
            max_rounds: 0,
            min_history: 3,
        }
    }
}

impl AgentConfig {
    /// The `PsheaConfig` these knobs describe (the server's job defaults).
    pub fn to_pshea(&self) -> crate::agent::PsheaConfig {
        crate::agent::PsheaConfig {
            target_accuracy: self.target_accuracy,
            max_budget: self.max_budget,
            round_budget: self.round_budget,
            converge_rounds: self.converge_rounds,
            converge_eps: self.converge_eps,
            max_rounds: self.max_rounds,
            min_history: self.min_history,
            initial_accuracy: None,
        }
    }
}

/// `al_worker.*` — server topology.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerConfig {
    /// Wire protocol; this build speaks `alaas-jsonrpc` (the gRPC
    /// substitution, DESIGN.md).
    pub protocol: String,
    pub host: String,
    pub port: u16,
    /// PJRT inference worker replicas (the Triton substitution).
    pub replicas: usize,
    /// Download-stage threads.
    pub fetch_threads: usize,
    /// Preprocess-stage threads.
    pub preprocess_threads: usize,
    /// Bounded queue capacity between stages (backpressure).
    pub queue_depth: usize,
    /// Max time a dynamic batch waits to fill before dispatch.
    pub batch_timeout_ms: u64,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            protocol: "alaas-jsonrpc".into(),
            host: "127.0.0.1".into(),
            port: 60035,
            replicas: 2,
            fetch_threads: 4,
            preprocess_threads: 2,
            queue_depth: 256,
            batch_timeout_ms: 20,
        }
    }
}

/// Object-store simulation (the S3 substitution; Fig 4c's knobs).
#[derive(Debug, Clone, PartialEq)]
pub struct StoreConfig {
    /// Per-GET latency in microseconds (request round trip).
    pub get_latency_us: u64,
    /// Simulated link bandwidth in MiB/s (0 = infinite).
    pub bandwidth_mib_s: f64,
    /// Latency jitter fraction (0.1 = +-10%), deterministic per key.
    pub jitter: f64,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig { get_latency_us: 300, bandwidth_mib_s: 120.0, jitter: 0.1 }
    }
}

/// How the coordinator splits a pushed pool across workers
/// (`cluster.shard_policy`; DESIGN.md §Cluster).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Contiguous ranges: shard i gets pool[i*chunk .. (i+1)*chunk].
    Contiguous,
    /// Round-robin: sample j goes to shard j % n (evens out any positional
    /// skew in the pushed manifest).
    Strided,
}

impl ShardPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShardPolicy::Contiguous => "contiguous",
            ShardPolicy::Strided => "strided",
        }
    }

    pub fn parse(s: &str) -> Option<ShardPolicy> {
        match s {
            "contiguous" => Some(ShardPolicy::Contiguous),
            "strided" => Some(ShardPolicy::Strided),
            _ => None,
        }
    }
}

/// `cluster.*` — the coordinator/worker scale-out topology (DESIGN.md
/// §Cluster). Empty `workers` means the coordinator starts with no static
/// members and relies on the `register` RPC.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Worker addresses ("host:port") the coordinator dispatches to.
    pub workers: Vec<String>,
    pub shard_policy: ShardPolicy,
    /// Candidate multiplier for the distributed diversity/hybrid
    /// strategies: each worker returns `oversample_factor * budget /
    /// n_workers` candidates for the coordinator's refine pass. Keep
    /// >= the expected worker count so the candidate union always covers
    /// a full budget.
    pub oversample_factor: usize,
    /// `cluster.membership.*` — heartbeat/lease live membership
    /// (`enabled`, `heartbeat_ms`, `lease_ms`). Disabled by default:
    /// static config + one-shot `register` keep working unchanged
    /// (DESIGN.md §Cluster).
    pub membership: MembershipConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            workers: vec![],
            shard_policy: ShardPolicy::Contiguous,
            oversample_factor: 4,
            membership: MembershipConfig::default(),
        }
    }
}

/// What the admission gate does with an arrival once the admit queue is
/// full (`coordinator.tenancy.shed_policy`; DESIGN.md §Tenancy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Reject the arriving scatter with `Overloaded{retry_after_ms}`.
    RejectNew,
    /// Evict the oldest queued scatter (it gets the `Overloaded` error)
    /// and queue the arrival in its place.
    DropOldest,
}

impl ShedPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShedPolicy::RejectNew => "reject_new",
            ShedPolicy::DropOldest => "drop_oldest",
        }
    }

    pub fn parse(s: &str) -> Option<ShedPolicy> {
        match s {
            "reject_new" => Some(ShedPolicy::RejectNew),
            "drop_oldest" => Some(ShedPolicy::DropOldest),
            _ => None,
        }
    }
}

/// `coordinator.tenancy.*` — multi-tenant admission control, weighted
/// fairness, and load shedding on the coordinator's scatter path
/// (DESIGN.md §Tenancy). Disabled by default: sessions bypass the gate
/// entirely and behave exactly as before.
#[derive(Debug, Clone, PartialEq)]
pub struct TenancyConfig {
    pub enabled: bool,
    /// Hard cap on registered sessions; `session_create` beyond it is
    /// rejected with `quota_exceeded`.
    pub max_sessions: usize,
    /// Cap on how many workers one session's pool is sharded across
    /// (0 = all live workers).
    pub max_workers_per_session: usize,
    /// Bounded admission queue in front of the scatter path; arrivals
    /// beyond it are shed per `shed_policy`.
    pub admit_queue_len: usize,
    /// Scatters allowed on the workers concurrently across all sessions.
    pub max_concurrent: usize,
    pub shed_policy: ShedPolicy,
}

impl Default for TenancyConfig {
    fn default() -> Self {
        TenancyConfig {
            enabled: false,
            max_sessions: 64,
            max_workers_per_session: 0,
            admit_queue_len: 32,
            max_concurrent: 4,
            shed_policy: ShedPolicy::RejectNew,
        }
    }
}

/// `coordinator.*` — coordinator-side service policy (the scatter data
/// path itself is configured under `cluster.*`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CoordinatorConfig {
    pub tenancy: TenancyConfig,
}

/// `server.*` — RPC data-plane settings (DESIGN.md §Wire).
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    /// Wire encoding this process *sends* and, server-side, whether v2
    /// binary requests are accepted: `binary` (default — v2 tensor
    /// frames, negotiated per peer with automatic JSON fallback) or
    /// `json` (force v1 frames only; v2 requests are refused with the
    /// stable `binary wire disabled` error). In YAML, `server.wire`
    /// takes either the bare mode string or a `{mode, mux}` mapping.
    pub wire: WireMode,
    /// `server.wire.mux` — request-id multiplexing on negotiated binary
    /// connections: many in-flight RPCs share one connection per peer
    /// (replies are matched by envelope id, so they may return out of
    /// order). On by default; negotiated per connection via `hello`, so
    /// either side switching it off falls back to the classic
    /// one-RPC-at-a-time exchange with no config coordination.
    pub mux: bool,
    /// `server.pool.*` — persistent-connection pool for outbound RPCs
    /// (`max_idle_per_peer`, `idle_timeout_ms`; `max_idle_per_peer: 0`
    /// disables reuse: every call dials + negotiates a fresh connection,
    /// and multiplexed connections are disabled too).
    pub pool: PoolConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { wire: WireMode::Binary, mux: true, pool: PoolConfig::default() }
    }
}

/// Data-cache settings (paper §3.3 "data cache").
#[derive(Debug, Clone, PartialEq)]
pub struct CacheConfig {
    pub enabled: bool,
    /// Capacity in MiB of processed samples.
    pub capacity_mib: usize,
    pub shards: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig { enabled: true, capacity_mib: 512, shards: 16 }
    }
}

/// `observability.*` — tracing and log-output knobs (DESIGN.md
/// §Observability).
#[derive(Debug, Clone, PartialEq)]
pub struct ObservabilityConfig {
    /// Span tracing on/off. Off leaves only an inert atomic check on the
    /// request path (<5% micro-hot-path overhead, pinned by test).
    pub trace: bool,
    /// Requests whose root span lasts at least this long are retained
    /// verbatim in the slow-query log (0 disables capture).
    pub slow_query_ms: u64,
    /// Log line format: `text` or `json`. The `ALAAS_LOG_FORMAT` env var
    /// outranks this.
    pub log_format: String,
}

impl Default for ObservabilityConfig {
    fn default() -> Self {
        ObservabilityConfig { trace: true, slow_query_ms: 500, log_format: "text".into() }
    }
}

/// Root config (Fig 2's `example.yml`).
#[derive(Debug, Clone, PartialEq)]
pub struct AlaasConfig {
    pub name: String,
    pub version: String,
    pub active_learning: ActiveLearningConfig,
    pub al_worker: WorkerConfig,
    pub store: StoreConfig,
    pub cache: CacheConfig,
    pub cluster: ClusterConfig,
    /// `coordinator.*` — multi-tenant admission control / fairness /
    /// shedding policy (DESIGN.md §Tenancy). Off by default.
    pub coordinator: CoordinatorConfig,
    pub server: ServerConfig,
    pub observability: ObservabilityConfig,
    /// `durability.*` — coordinator WAL + snapshot crash safety
    /// (`enabled`, `data_dir`, `fsync`, `snapshot_every`,
    /// `max_wal_bytes`; DESIGN.md §Durability). Disabled by default:
    /// state stays in RAM exactly as before. `max_wal_bytes` (0 = off)
    /// forces a rotate+snapshot even while jobs run, so a multi-hour job
    /// cannot grow the WAL without bound.
    pub durability: DurabilityConfig,
    /// Directory holding `manifest.json` + `*.hlo.txt` from `make artifacts`.
    pub artifacts_dir: String,
}

impl Default for AlaasConfig {
    fn default() -> Self {
        AlaasConfig {
            name: "ALAAS".into(),
            version: "0.1".into(),
            active_learning: ActiveLearningConfig::default(),
            al_worker: WorkerConfig::default(),
            store: StoreConfig::default(),
            cache: CacheConfig::default(),
            cluster: ClusterConfig::default(),
            coordinator: CoordinatorConfig::default(),
            server: ServerConfig::default(),
            observability: ObservabilityConfig::default(),
            durability: DurabilityConfig::default(),
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl AlaasConfig {
    /// Parse + validate a YAML config string.
    pub fn from_yaml_str(s: &str) -> Result<AlaasConfig, ConfigError> {
        let v = yamlmini::parse(s).map_err(|e| cerr("<yaml>", e.to_string()))?;
        Self::from_value(&v)
    }

    /// Load from a file path.
    pub fn from_yaml_file(path: &str) -> Result<AlaasConfig, ConfigError> {
        let s = std::fs::read_to_string(path)
            .map_err(|e| cerr("<file>", format!("{path}: {e}")))?;
        Self::from_yaml_str(&s)
    }

    /// Build from a parsed Value, applying defaults and validating.
    pub fn from_value(v: &Value) -> Result<AlaasConfig, ConfigError> {
        let mut cfg = AlaasConfig::default();
        if v.is_null() {
            return Ok(cfg);
        }
        if v.as_object().is_none() {
            return Err(cerr("<root>", "config must be a mapping"));
        }

        if let Some(x) = v.get("name") {
            cfg.name = req_str(x, "name")?;
        }
        if let Some(x) = v.get("version") {
            cfg.version = match x {
                Value::String(s) => s.clone(),
                Value::Number(n) => format!("{n}"),
                _ => return Err(cerr("version", "expected string or number")),
            };
        }
        if let Some(x) = v.get("artifacts_dir") {
            cfg.artifacts_dir = req_str(x, "artifacts_dir")?;
        }

        if let Some(al) = v.get("active_learning") {
            let c = &mut cfg.active_learning;
            if let Some(s) = al.path("strategy.type") {
                let name = req_str(s, "active_learning.strategy.type")?;
                c.strategy = if name == "auto" {
                    StrategyChoice::Auto
                } else {
                    StrategyChoice::Named(name)
                };
            }
            if let Some(m) = al.get("model") {
                if let Some(x) = m.get("name") {
                    c.model.name = req_str(x, "active_learning.model.name")?;
                }
                if let Some(x) = m.get("hub_name") {
                    c.model.hub_name = req_str(x, "active_learning.model.hub_name")?;
                }
                if let Some(x) = m.get("batch_size") {
                    c.model.batch_size = req_usize(x, "active_learning.model.batch_size")?;
                }
            }
            if let Some(x) = al.get("device") {
                c.device = req_str(x, "active_learning.device")?;
            }
            if let Some(a) = al.get("agent") {
                if let Some(x) = a.get("target_accuracy") {
                    c.agent.target_accuracy = req_f64(x, "active_learning.agent.target_accuracy")?;
                }
                if let Some(x) = a.get("max_budget") {
                    c.agent.max_budget = req_usize(x, "active_learning.agent.max_budget")?;
                }
                if let Some(x) = a.get("round_budget") {
                    c.agent.round_budget = req_usize(x, "active_learning.agent.round_budget")?;
                }
                if let Some(x) = a.get("converge_rounds") {
                    c.agent.converge_rounds =
                        req_usize(x, "active_learning.agent.converge_rounds")?;
                }
                if let Some(x) = a.get("converge_eps") {
                    c.agent.converge_eps = req_f64(x, "active_learning.agent.converge_eps")?;
                }
                if let Some(x) = a.get("max_rounds") {
                    c.agent.max_rounds = req_usize(x, "active_learning.agent.max_rounds")?;
                }
                if let Some(x) = a.get("min_history") {
                    c.agent.min_history = req_usize(x, "active_learning.agent.min_history")?;
                }
            }
        }

        if let Some(w) = v.get("al_worker") {
            let c = &mut cfg.al_worker;
            if let Some(x) = w.get("protocol") {
                c.protocol = req_str(x, "al_worker.protocol")?;
            }
            if let Some(x) = w.get("host") {
                c.host = req_str(x, "al_worker.host")?;
            }
            if let Some(x) = w.get("port") {
                let p = req_usize(x, "al_worker.port")?;
                c.port = u16::try_from(p).map_err(|_| cerr("al_worker.port", "out of range"))?;
            }
            if let Some(x) = w.get("replicas") {
                c.replicas = req_usize(x, "al_worker.replicas")?;
            }
            if let Some(x) = w.get("fetch_threads") {
                c.fetch_threads = req_usize(x, "al_worker.fetch_threads")?;
            }
            if let Some(x) = w.get("preprocess_threads") {
                c.preprocess_threads = req_usize(x, "al_worker.preprocess_threads")?;
            }
            if let Some(x) = w.get("queue_depth") {
                c.queue_depth = req_usize(x, "al_worker.queue_depth")?;
            }
            if let Some(x) = w.get("batch_timeout_ms") {
                c.batch_timeout_ms = req_usize(x, "al_worker.batch_timeout_ms")? as u64;
            }
        }

        if let Some(s) = v.get("store") {
            let c = &mut cfg.store;
            if let Some(x) = s.get("get_latency_us") {
                c.get_latency_us = req_usize(x, "store.get_latency_us")? as u64;
            }
            if let Some(x) = s.get("bandwidth_mib_s") {
                c.bandwidth_mib_s = req_f64(x, "store.bandwidth_mib_s")?;
            }
            if let Some(x) = s.get("jitter") {
                c.jitter = req_f64(x, "store.jitter")?;
            }
        }

        if let Some(s) = v.get("cluster") {
            let c = &mut cfg.cluster;
            if let Some(x) = s.get("workers") {
                let arr = x
                    .as_array()
                    .ok_or_else(|| cerr("cluster.workers", "expected list of \"host:port\""))?;
                c.workers = arr
                    .iter()
                    .map(|w| req_str(w, "cluster.workers[]"))
                    .collect::<Result<Vec<_>, _>>()?;
            }
            if let Some(x) = s.get("shard_policy") {
                let name = req_str(x, "cluster.shard_policy")?;
                c.shard_policy = ShardPolicy::parse(&name).ok_or_else(|| {
                    cerr(
                        "cluster.shard_policy",
                        format!("unknown policy '{name}' (contiguous|strided)"),
                    )
                })?;
            }
            if let Some(x) = s.get("oversample_factor") {
                c.oversample_factor = req_usize(x, "cluster.oversample_factor")?;
            }
            if let Some(m) = s.get("membership") {
                if let Some(x) = m.get("enabled") {
                    c.membership.enabled = x
                        .as_bool()
                        .ok_or_else(|| cerr("cluster.membership.enabled", "expected bool"))?;
                }
                if let Some(x) = m.get("heartbeat_ms") {
                    c.membership.heartbeat_ms =
                        req_usize(x, "cluster.membership.heartbeat_ms")? as u64;
                }
                if let Some(x) = m.get("lease_ms") {
                    c.membership.lease_ms =
                        req_usize(x, "cluster.membership.lease_ms")? as u64;
                }
            }
        }

        if let Some(s) = v.get("coordinator") {
            if let Some(t) = s.get("tenancy") {
                let c = &mut cfg.coordinator.tenancy;
                if let Some(x) = t.get("enabled") {
                    c.enabled = x
                        .as_bool()
                        .ok_or_else(|| cerr("coordinator.tenancy.enabled", "expected bool"))?;
                }
                if let Some(x) = t.get("max_sessions") {
                    c.max_sessions = req_usize(x, "coordinator.tenancy.max_sessions")?;
                }
                if let Some(x) = t.get("max_workers_per_session") {
                    c.max_workers_per_session =
                        req_usize(x, "coordinator.tenancy.max_workers_per_session")?;
                }
                if let Some(x) = t.get("admit_queue_len") {
                    c.admit_queue_len = req_usize(x, "coordinator.tenancy.admit_queue_len")?;
                }
                if let Some(x) = t.get("max_concurrent") {
                    c.max_concurrent = req_usize(x, "coordinator.tenancy.max_concurrent")?;
                }
                if let Some(x) = t.get("shed_policy") {
                    let name = req_str(x, "coordinator.tenancy.shed_policy")?;
                    c.shed_policy = ShedPolicy::parse(&name).ok_or_else(|| {
                        cerr(
                            "coordinator.tenancy.shed_policy",
                            format!("unknown policy '{name}' (reject_new|drop_oldest)"),
                        )
                    })?;
                }
            }
        }

        if let Some(s) = v.get("server") {
            let c = &mut cfg.server;
            if let Some(x) = s.get("wire") {
                // scalar form (`wire: binary`) or mapping form
                // (`wire: {mode: binary, mux: false}`)
                if let Some(name) = x.as_str() {
                    c.wire = WireMode::parse(name).ok_or_else(|| {
                        cerr("server.wire", format!("unknown wire mode '{name}' (json|binary)"))
                    })?;
                } else if x.as_object().is_some() {
                    if let Some(m) = x.get("mode") {
                        let name = req_str(m, "server.wire.mode")?;
                        c.wire = WireMode::parse(&name).ok_or_else(|| {
                            cerr(
                                "server.wire.mode",
                                format!("unknown wire mode '{name}' (json|binary)"),
                            )
                        })?;
                    }
                    if let Some(b) = x.get("mux") {
                        c.mux = b
                            .as_bool()
                            .ok_or_else(|| cerr("server.wire.mux", "expected bool"))?;
                    }
                } else {
                    return Err(cerr(
                        "server.wire",
                        "expected a wire mode string or a {mode, mux} mapping",
                    ));
                }
            }
            if let Some(p) = s.get("pool") {
                if let Some(x) = p.get("max_idle_per_peer") {
                    c.pool.max_idle_per_peer = req_usize(x, "server.pool.max_idle_per_peer")?;
                }
                if let Some(x) = p.get("idle_timeout_ms") {
                    c.pool.idle_timeout_ms =
                        req_usize(x, "server.pool.idle_timeout_ms")? as u64;
                }
            }
        }

        if let Some(s) = v.get("cache") {
            let c = &mut cfg.cache;
            if let Some(x) = s.get("enabled") {
                c.enabled =
                    x.as_bool().ok_or_else(|| cerr("cache.enabled", "expected bool"))?;
            }
            if let Some(x) = s.get("capacity_mib") {
                c.capacity_mib = req_usize(x, "cache.capacity_mib")?;
            }
            if let Some(x) = s.get("shards") {
                c.shards = req_usize(x, "cache.shards")?;
            }
        }

        if let Some(s) = v.get("durability") {
            let c = &mut cfg.durability;
            if let Some(x) = s.get("enabled") {
                c.enabled =
                    x.as_bool().ok_or_else(|| cerr("durability.enabled", "expected bool"))?;
            }
            if let Some(x) = s.get("data_dir") {
                c.data_dir = req_str(x, "durability.data_dir")?;
            }
            if let Some(x) = s.get("fsync") {
                let name = req_str(x, "durability.fsync")?;
                c.fsync = FsyncPolicy::parse(&name).ok_or_else(|| {
                    cerr("durability.fsync", format!("unknown policy '{name}' (always|never)"))
                })?;
            }
            if let Some(x) = s.get("snapshot_every") {
                c.snapshot_every = req_usize(x, "durability.snapshot_every")?;
            }
            if let Some(x) = s.get("max_wal_bytes") {
                c.max_wal_bytes = req_usize(x, "durability.max_wal_bytes")? as u64;
            }
        }

        if let Some(s) = v.get("observability") {
            let c = &mut cfg.observability;
            if let Some(x) = s.get("trace") {
                c.trace =
                    x.as_bool().ok_or_else(|| cerr("observability.trace", "expected bool"))?;
            }
            if let Some(x) = s.get("slow_query_ms") {
                c.slow_query_ms = req_usize(x, "observability.slow_query_ms")? as u64;
            }
            if let Some(x) = s.get("log_format") {
                c.log_format = req_str(x, "observability.log_format")?;
            }
        }

        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-field validation.
    pub fn validate(&self) -> Result<(), ConfigError> {
        let bs = self.active_learning.model.batch_size;
        if bs == 0 {
            return Err(cerr("active_learning.model.batch_size", "must be >= 1"));
        }
        if !bs.is_power_of_two() || bs > 128 {
            return Err(cerr(
                "active_learning.model.batch_size",
                format!("must be a power of two <= 128 (compiled artifact variants); got {bs}"),
            ));
        }
        if self.active_learning.device != "CPU" {
            return Err(cerr(
                "active_learning.device",
                format!("only CPU PJRT is available in this build; got {}", self.active_learning.device),
            ));
        }
        if self.al_worker.replicas == 0 {
            return Err(cerr("al_worker.replicas", "must be >= 1"));
        }
        if self.al_worker.queue_depth == 0 {
            return Err(cerr("al_worker.queue_depth", "must be >= 1"));
        }
        let a = &self.active_learning.agent;
        if !(0.0..=1.0).contains(&a.target_accuracy) {
            return Err(cerr("active_learning.agent.target_accuracy", "must be in [0, 1]"));
        }
        if a.round_budget == 0 || a.round_budget > a.max_budget {
            return Err(cerr(
                "active_learning.agent.round_budget",
                "must be in [1, max_budget]",
            ));
        }
        if a.min_history == 0 {
            return Err(cerr(
                "active_learning.agent.min_history",
                "must be >= 1 (the predictor needs history before killing arms)",
            ));
        }
        if self.cache.shards == 0 {
            return Err(cerr("cache.shards", "must be >= 1"));
        }
        if self.cluster.oversample_factor == 0 {
            return Err(cerr("cluster.oversample_factor", "must be >= 1"));
        }
        for w in &self.cluster.workers {
            if !w.contains(':') {
                return Err(cerr(
                    "cluster.workers",
                    format!("worker address '{w}' is not host:port"),
                ));
            }
        }
        let mem = &self.cluster.membership;
        if mem.heartbeat_ms == 0 {
            return Err(cerr("cluster.membership.heartbeat_ms", "must be >= 1"));
        }
        if mem.lease_ms < 2 * mem.heartbeat_ms {
            return Err(cerr(
                "cluster.membership.lease_ms",
                format!(
                    "must be >= 2 * heartbeat_ms ({}) so one lost beat cannot \
                     expire a live worker; got {}",
                    2 * mem.heartbeat_ms,
                    mem.lease_ms
                ),
            ));
        }
        let t = &self.coordinator.tenancy;
        if t.enabled {
            if t.max_sessions == 0 {
                return Err(cerr("coordinator.tenancy.max_sessions", "must be >= 1"));
            }
            if t.admit_queue_len == 0 {
                return Err(cerr(
                    "coordinator.tenancy.admit_queue_len",
                    "must be >= 1 (a zero-length queue sheds every concurrent scatter)",
                ));
            }
            if t.max_concurrent == 0 {
                return Err(cerr("coordinator.tenancy.max_concurrent", "must be >= 1"));
            }
        }
        if !(0.0..1.0).contains(&self.store.jitter) {
            return Err(cerr("store.jitter", "must be in [0, 1)"));
        }
        if self.server.pool.idle_timeout_ms == 0 {
            return Err(cerr(
                "server.pool.idle_timeout_ms",
                "must be >= 1 (set pool.max_idle_per_peer: 0 to disable reuse instead)",
            ));
        }
        let fmt = self.observability.log_format.as_str();
        if crate::util::logger::Format::parse(fmt).is_none() {
            return Err(cerr(
                "observability.log_format",
                format!("unknown log format '{fmt}' (text|json)"),
            ));
        }
        let d = &self.durability;
        if d.snapshot_every == 0 {
            return Err(cerr("durability.snapshot_every", "must be >= 1"));
        }
        // a cap smaller than one frame would force a compaction on every
        // append; require something sane or 0 (disabled)
        if d.max_wal_bytes != 0 && d.max_wal_bytes < 1024 {
            return Err(cerr("durability.max_wal_bytes", "must be 0 (disabled) or >= 1024"));
        }
        if d.enabled && d.data_dir.is_empty() {
            return Err(cerr("durability.data_dir", "must be non-empty when durability is enabled"));
        }
        Ok(())
    }
}

fn req_str(v: &Value, field: &str) -> Result<String, ConfigError> {
    v.as_str().map(str::to_string).ok_or_else(|| cerr(field, "expected string"))
}

fn req_usize(v: &Value, field: &str) -> Result<usize, ConfigError> {
    v.as_usize().ok_or_else(|| cerr(field, "expected non-negative integer"))
}

fn req_f64(v: &Value, field: &str) -> Result<f64, ConfigError> {
    v.as_f64().ok_or_else(|| cerr(field, "expected number"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        AlaasConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_fig2_style_config() {
        let cfg = AlaasConfig::from_yaml_str(
            r#"
name: "IMG_CLASSIFICATION"
version: 0.1
active_learning:
  strategy:
    type: "auto"
  model:
    name: "resnet18"
    hub_name: "pytorch/vision:release/0.12"
    batch_size: 1
  device: CPU
al_worker:
  protocol: "alaas-jsonrpc"
  host: "0.0.0.0"
  port: 60035
  replicas: 1
"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "IMG_CLASSIFICATION");
        assert_eq!(cfg.version, "0.1");
        assert_eq!(cfg.active_learning.strategy, StrategyChoice::Auto);
        assert_eq!(cfg.active_learning.model.batch_size, 1);
        assert_eq!(cfg.al_worker.port, 60035);
        assert_eq!(cfg.al_worker.replicas, 1);
        // untouched fields keep defaults
        assert_eq!(cfg.cache.capacity_mib, 512);
    }

    #[test]
    fn named_strategy() {
        let cfg = AlaasConfig::from_yaml_str(
            "active_learning:\n  strategy:\n    type: \"core_set\"\n",
        )
        .unwrap();
        assert_eq!(cfg.active_learning.strategy, StrategyChoice::Named("core_set".into()));
    }

    #[test]
    fn empty_config_is_all_defaults() {
        let cfg = AlaasConfig::from_yaml_str("").unwrap();
        assert_eq!(cfg, AlaasConfig::default());
    }

    #[test]
    fn rejects_bad_batch_size() {
        for bs in ["0", "3", "256"] {
            let doc = format!("active_learning:\n  model:\n    batch_size: {bs}\n");
            let e = AlaasConfig::from_yaml_str(&doc).unwrap_err();
            assert_eq!(e.field, "active_learning.model.batch_size", "{bs}: {e}");
        }
    }

    #[test]
    fn rejects_gpu_device() {
        let e = AlaasConfig::from_yaml_str("active_learning:\n  device: GPU\n").unwrap_err();
        assert_eq!(e.field, "active_learning.device");
    }

    #[test]
    fn rejects_zero_replicas_and_bad_port() {
        assert!(AlaasConfig::from_yaml_str("al_worker:\n  replicas: 0\n").is_err());
        assert!(AlaasConfig::from_yaml_str("al_worker:\n  port: 99999\n").is_err());
    }

    #[test]
    fn rejects_type_confusion() {
        assert!(AlaasConfig::from_yaml_str("name:\n  nested: 1\n").is_err());
        assert!(AlaasConfig::from_yaml_str("al_worker:\n  port: \"sixty\"\n").is_err());
        assert!(AlaasConfig::from_yaml_str("cache:\n  enabled: 3\n").is_err());
    }

    #[test]
    fn parses_cluster_section() {
        let cfg = AlaasConfig::from_yaml_str(
            r#"
cluster:
  workers:
    - "127.0.0.1:60036"
    - "127.0.0.1:60037"
  shard_policy: strided
  oversample_factor: 6
"#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.workers.len(), 2);
        assert_eq!(cfg.cluster.workers[1], "127.0.0.1:60037");
        assert_eq!(cfg.cluster.shard_policy, ShardPolicy::Strided);
        assert_eq!(cfg.cluster.oversample_factor, 6);
    }

    #[test]
    fn cluster_defaults_and_validation() {
        let cfg = AlaasConfig::from_yaml_str("").unwrap();
        assert!(cfg.cluster.workers.is_empty());
        assert_eq!(cfg.cluster.shard_policy, ShardPolicy::Contiguous);
        assert_eq!(cfg.cluster.oversample_factor, 4);

        let e = AlaasConfig::from_yaml_str("cluster:\n  shard_policy: diagonal\n").unwrap_err();
        assert_eq!(e.field, "cluster.shard_policy");
        let e =
            AlaasConfig::from_yaml_str("cluster:\n  oversample_factor: 0\n").unwrap_err();
        assert_eq!(e.field, "cluster.oversample_factor");
        let e = AlaasConfig::from_yaml_str("cluster:\n  workers: [noport]\n").unwrap_err();
        assert_eq!(e.field, "cluster.workers");
        let e = AlaasConfig::from_yaml_str("cluster:\n  workers: 3\n").unwrap_err();
        assert_eq!(e.field, "cluster.workers");
    }

    #[test]
    fn parses_cluster_membership_section() {
        let cfg = AlaasConfig::from_yaml_str(
            r#"
cluster:
  membership:
    enabled: true
    heartbeat_ms: 250
    lease_ms: 1500
"#,
        )
        .unwrap();
        let m = &cfg.cluster.membership;
        assert!(m.enabled);
        assert_eq!(m.heartbeat_ms, 250);
        assert_eq!(m.lease_ms, 1500);
        // defaults: disabled, static-config fallback
        let d = AlaasConfig::default().cluster.membership;
        assert!(!d.enabled);
        assert_eq!(d.heartbeat_ms, 500);
        assert_eq!(d.lease_ms, 2500);
        AlaasConfig::default().validate().unwrap();
    }

    #[test]
    fn membership_validation_rejects_tight_or_zero_leases() {
        // a lease shorter than two heartbeats would expire live workers
        let e = AlaasConfig::from_yaml_str(
            "cluster:\n  membership:\n    heartbeat_ms: 500\n    lease_ms: 900\n",
        )
        .unwrap_err();
        assert_eq!(e.field, "cluster.membership.lease_ms");
        let e = AlaasConfig::from_yaml_str(
            "cluster:\n  membership:\n    heartbeat_ms: 0\n",
        )
        .unwrap_err();
        assert_eq!(e.field, "cluster.membership.heartbeat_ms");
        let e = AlaasConfig::from_yaml_str(
            "cluster:\n  membership:\n    enabled: 3\n",
        )
        .unwrap_err();
        assert_eq!(e.field, "cluster.membership.enabled");
    }

    #[test]
    fn parses_server_wire_knob() {
        let cfg = AlaasConfig::from_yaml_str("server:\n  wire: json\n").unwrap();
        assert_eq!(cfg.server.wire, WireMode::Json);
        let cfg = AlaasConfig::from_yaml_str("server:\n  wire: binary\n").unwrap();
        assert_eq!(cfg.server.wire, WireMode::Binary);
        // default prefers the binary data plane
        assert_eq!(AlaasConfig::default().server.wire, WireMode::Binary);
        let e = AlaasConfig::from_yaml_str("server:\n  wire: msgpack\n").unwrap_err();
        assert_eq!(e.field, "server.wire");
    }

    #[test]
    fn parses_server_wire_mux_knob() {
        // default: mux on, and the scalar wire form leaves it untouched
        assert!(AlaasConfig::default().server.mux);
        let cfg = AlaasConfig::from_yaml_str("server:\n  wire: json\n").unwrap();
        assert!(cfg.server.mux);
        // mapping form sets both mode and mux
        let cfg = AlaasConfig::from_yaml_str(
            "server:\n  wire:\n    mode: binary\n    mux: false\n",
        )
        .unwrap();
        assert_eq!(cfg.server.wire, WireMode::Binary);
        assert!(!cfg.server.mux);
        // mux alone keeps the default mode
        let cfg = AlaasConfig::from_yaml_str("server:\n  wire:\n    mux: true\n").unwrap();
        assert_eq!(cfg.server.wire, WireMode::Binary);
        assert!(cfg.server.mux);
        let e = AlaasConfig::from_yaml_str("server:\n  wire:\n    mux: 3\n").unwrap_err();
        assert_eq!(e.field, "server.wire.mux");
        let e = AlaasConfig::from_yaml_str(
            "server:\n  wire:\n    mode: msgpack\n",
        )
        .unwrap_err();
        assert_eq!(e.field, "server.wire.mode");
    }

    #[test]
    fn parses_server_pool_knobs() {
        let cfg = AlaasConfig::from_yaml_str(
            "server:\n  pool:\n    max_idle_per_peer: 8\n    idle_timeout_ms: 5000\n",
        )
        .unwrap();
        assert_eq!(cfg.server.pool.max_idle_per_peer, 8);
        assert_eq!(cfg.server.pool.idle_timeout_ms, 5000);
        // defaults: pooling on
        let d = AlaasConfig::default().server.pool;
        assert_eq!(d.max_idle_per_peer, 4);
        assert_eq!(d.idle_timeout_ms, 30_000);
        // 0 = per-call dialing is a legal escape hatch ...
        let cfg = AlaasConfig::from_yaml_str(
            "server:\n  pool:\n    max_idle_per_peer: 0\n",
        )
        .unwrap();
        assert_eq!(cfg.server.pool.max_idle_per_peer, 0);
        // ... but a zero idle timeout is a config error, not a footgun
        let e = AlaasConfig::from_yaml_str(
            "server:\n  pool:\n    idle_timeout_ms: 0\n",
        )
        .unwrap_err();
        assert_eq!(e.field, "server.pool.idle_timeout_ms");
        let e = AlaasConfig::from_yaml_str(
            "server:\n  pool:\n    max_idle_per_peer: \"many\"\n",
        )
        .unwrap_err();
        assert_eq!(e.field, "server.pool.max_idle_per_peer");
    }

    #[test]
    fn parses_observability_section() {
        let cfg = AlaasConfig::from_yaml_str(
            r#"
observability:
  trace: false
  slow_query_ms: 250
  log_format: json
"#,
        )
        .unwrap();
        let o = &cfg.observability;
        assert!(!o.trace);
        assert_eq!(o.slow_query_ms, 250);
        assert_eq!(o.log_format, "json");
        // defaults: tracing on, 500ms slow-query threshold, text logs
        let d = AlaasConfig::default().observability;
        assert!(d.trace);
        assert_eq!(d.slow_query_ms, 500);
        assert_eq!(d.log_format, "text");
    }

    #[test]
    fn observability_validation() {
        let e = AlaasConfig::from_yaml_str("observability:\n  log_format: xml\n").unwrap_err();
        assert_eq!(e.field, "observability.log_format");
        let e = AlaasConfig::from_yaml_str("observability:\n  trace: 3\n").unwrap_err();
        assert_eq!(e.field, "observability.trace");
        let e =
            AlaasConfig::from_yaml_str("observability:\n  slow_query_ms: \"fast\"\n").unwrap_err();
        assert_eq!(e.field, "observability.slow_query_ms");
        // slow_query_ms: 0 legitimately disables slow-query capture
        let cfg = AlaasConfig::from_yaml_str("observability:\n  slow_query_ms: 0\n").unwrap();
        assert_eq!(cfg.observability.slow_query_ms, 0);
    }

    #[test]
    fn parses_durability_section() {
        let cfg = AlaasConfig::from_yaml_str(
            r#"
durability:
  enabled: true
  data_dir: "/var/lib/alaas"
  fsync: never
  snapshot_every: 64
  max_wal_bytes: 1048576
"#,
        )
        .unwrap();
        let d = &cfg.durability;
        assert!(d.enabled);
        assert_eq!(d.data_dir, "/var/lib/alaas");
        assert_eq!(d.fsync, FsyncPolicy::Never);
        assert_eq!(d.snapshot_every, 64);
        assert_eq!(d.max_wal_bytes, 1_048_576);
        // defaults: off, always-fsync, no byte cap, state stays in RAM
        let d = AlaasConfig::default().durability;
        assert!(!d.enabled);
        assert_eq!(d.fsync, FsyncPolicy::Always);
        assert_eq!(d.snapshot_every, 256);
        assert_eq!(d.max_wal_bytes, 0);
    }

    #[test]
    fn durability_validation() {
        let e = AlaasConfig::from_yaml_str("durability:\n  fsync: sometimes\n").unwrap_err();
        assert_eq!(e.field, "durability.fsync");
        let e =
            AlaasConfig::from_yaml_str("durability:\n  snapshot_every: 0\n").unwrap_err();
        assert_eq!(e.field, "durability.snapshot_every");
        let e =
            AlaasConfig::from_yaml_str("durability:\n  max_wal_bytes: 100\n").unwrap_err();
        assert_eq!(e.field, "durability.max_wal_bytes");
        let cfg = AlaasConfig::from_yaml_str("durability:\n  max_wal_bytes: 0\n").unwrap();
        assert_eq!(cfg.durability.max_wal_bytes, 0);
        let e = AlaasConfig::from_yaml_str(
            "durability:\n  enabled: true\n  data_dir: \"\"\n",
        )
        .unwrap_err();
        assert_eq!(e.field, "durability.data_dir");
        let e = AlaasConfig::from_yaml_str("durability:\n  enabled: 3\n").unwrap_err();
        assert_eq!(e.field, "durability.enabled");
    }

    #[test]
    fn parses_coordinator_tenancy_section() {
        let cfg = AlaasConfig::from_yaml_str(
            r#"
coordinator:
  tenancy:
    enabled: true
    max_sessions: 8
    max_workers_per_session: 2
    admit_queue_len: 16
    max_concurrent: 3
    shed_policy: drop_oldest
"#,
        )
        .unwrap();
        let t = &cfg.coordinator.tenancy;
        assert!(t.enabled);
        assert_eq!(t.max_sessions, 8);
        assert_eq!(t.max_workers_per_session, 2);
        assert_eq!(t.admit_queue_len, 16);
        assert_eq!(t.max_concurrent, 3);
        assert_eq!(t.shed_policy, ShedPolicy::DropOldest);
        // defaults: gate off, everything passes through untouched
        let d = AlaasConfig::default().coordinator.tenancy;
        assert!(!d.enabled);
        assert_eq!(d.max_sessions, 64);
        assert_eq!(d.max_workers_per_session, 0);
        assert_eq!(d.admit_queue_len, 32);
        assert_eq!(d.max_concurrent, 4);
        assert_eq!(d.shed_policy, ShedPolicy::RejectNew);
    }

    #[test]
    fn tenancy_validation() {
        let e = AlaasConfig::from_yaml_str(
            "coordinator:\n  tenancy:\n    shed_policy: coinflip\n",
        )
        .unwrap_err();
        assert_eq!(e.field, "coordinator.tenancy.shed_policy");
        let e = AlaasConfig::from_yaml_str(
            "coordinator:\n  tenancy:\n    enabled: true\n    max_sessions: 0\n",
        )
        .unwrap_err();
        assert_eq!(e.field, "coordinator.tenancy.max_sessions");
        let e = AlaasConfig::from_yaml_str(
            "coordinator:\n  tenancy:\n    enabled: true\n    admit_queue_len: 0\n",
        )
        .unwrap_err();
        assert_eq!(e.field, "coordinator.tenancy.admit_queue_len");
        let e = AlaasConfig::from_yaml_str(
            "coordinator:\n  tenancy:\n    enabled: true\n    max_concurrent: 0\n",
        )
        .unwrap_err();
        assert_eq!(e.field, "coordinator.tenancy.max_concurrent");
        let e = AlaasConfig::from_yaml_str(
            "coordinator:\n  tenancy:\n    enabled: 3\n",
        )
        .unwrap_err();
        assert_eq!(e.field, "coordinator.tenancy.enabled");
        // zero knobs are fine while the gate is disabled (defaults apply
        // only when someone turns it on)
        let cfg = AlaasConfig::from_yaml_str(
            "coordinator:\n  tenancy:\n    max_concurrent: 0\n",
        )
        .unwrap();
        assert!(!cfg.coordinator.tenancy.enabled);
    }

    #[test]
    fn agent_validation() {
        let e = AlaasConfig::from_yaml_str(
            "active_learning:\n  agent:\n    target_accuracy: 1.5\n",
        )
        .unwrap_err();
        assert_eq!(e.field, "active_learning.agent.target_accuracy");
        let e = AlaasConfig::from_yaml_str(
            "active_learning:\n  agent:\n    round_budget: 999999\n",
        )
        .unwrap_err();
        assert_eq!(e.field, "active_learning.agent.round_budget");
        let e = AlaasConfig::from_yaml_str(
            "active_learning:\n  agent:\n    min_history: 0\n",
        )
        .unwrap_err();
        assert_eq!(e.field, "active_learning.agent.min_history");
    }

    #[test]
    fn agent_section_carries_the_full_pshea_surface() {
        let cfg = AlaasConfig::from_yaml_str(
            r#"
active_learning:
  agent:
    target_accuracy: 0.9
    max_budget: 4000
    round_budget: 100
    converge_rounds: 5
    converge_eps: 0.01
    max_rounds: 12
    min_history: 2
"#,
        )
        .unwrap();
        let a = &cfg.active_learning.agent;
        assert_eq!(a.converge_rounds, 5);
        assert_eq!(a.max_rounds, 12);
        assert_eq!(a.min_history, 2);
        let p = a.to_pshea();
        assert_eq!(p.round_budget, 100);
        assert_eq!(p.max_rounds, 12);
        assert_eq!(p.min_history, 2);
        assert_eq!(p.initial_accuracy, None);
        assert!((p.converge_eps - 0.01).abs() < 1e-12);
        // defaults mirror PsheaConfig's defaults exactly
        let d = AgentConfig::default().to_pshea();
        let pd = crate::agent::PsheaConfig::default();
        assert_eq!(d.round_budget, pd.round_budget);
        assert_eq!(d.min_history, pd.min_history);
        assert_eq!(d.max_rounds, pd.max_rounds);
        assert_eq!(d.converge_rounds, pd.converge_rounds);
    }
}

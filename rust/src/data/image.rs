//! Raw sample codec + preprocessing.
//!
//! A stored sample is exactly `IMG_BYTES` raw u8 values (32*32*3, HWC).
//! Preprocessing mirrors python/compile tests: `u8 / 255 - 0.5`, i.e. the
//! float image the trunk was "trained" on. The preprocess stage of the
//! pipeline calls `decode_image`; the dataset generator calls
//! `encode_image`.

/// 32 * 32 * 3 — keep in sync with python/compile/model.py::IMG_DIM.
pub const IMG_DIM: usize = 3072;
/// Stored blob size in bytes (1 byte per component).
pub const IMG_BYTES: usize = IMG_DIM;

/// Decode error.
#[derive(Debug, thiserror::Error)]
#[error("bad image blob: expected {IMG_BYTES} bytes, got {0}")]
pub struct BadImage(pub usize);

/// Quantize a float image in [-0.5, 0.5] to the stored u8 form.
pub fn encode_image(pixels: &[f32]) -> Vec<u8> {
    assert_eq!(pixels.len(), IMG_DIM, "encode_image: wrong length");
    pixels
        .iter()
        .map(|&p| {
            let v = ((p + 0.5) * 255.0).round();
            v.clamp(0.0, 255.0) as u8
        })
        .collect()
}

/// Decode + preprocess a stored blob into the model's input range.
pub fn decode_image(blob: &[u8]) -> Result<Vec<f32>, BadImage> {
    if blob.len() != IMG_BYTES {
        return Err(BadImage(blob.len()));
    }
    Ok(blob.iter().map(|&b| b as f32 / 255.0 - 0.5).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_within_quantization_error() {
        let mut rng = crate::util::rng::Rng::new(4);
        let img: Vec<f32> = (0..IMG_DIM).map(|_| rng.range_f32(-0.5, 0.5)).collect();
        let blob = encode_image(&img);
        let back = decode_image(&blob).unwrap();
        for (a, b) in img.iter().zip(&back) {
            assert!((a - b).abs() <= 0.5 / 255.0 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn clamps_out_of_range() {
        let mut img = vec![0.0f32; IMG_DIM];
        img[0] = 5.0;
        img[1] = -5.0;
        let blob = encode_image(&img);
        assert_eq!(blob[0], 255);
        assert_eq!(blob[1], 0);
    }

    #[test]
    fn rejects_wrong_size() {
        assert!(decode_image(&[0u8; 100]).is_err());
        assert!(decode_image(&vec![0u8; IMG_BYTES]).is_ok());
    }

    #[test]
    fn decode_range() {
        let blob: Vec<u8> = (0..IMG_BYTES).map(|i| (i % 256) as u8).collect();
        let img = decode_image(&blob).unwrap();
        assert!(img.iter().all(|&p| (-0.5..=0.5).contains(&p)));
    }
}

//! The labeling oracle — the "human annotator" boundary of Figure 1.
//!
//! AL evaluation convention: ground-truth labels exist (labels.json in the
//! dataset bucket) but the system may only read them through `Oracle::
//! label`, which counts every revealed label against the budget. Code
//! outside this module never touches labels.json (the manifest test
//! enforces that manifests don't carry labels).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::json::Value;
use crate::store::{ObjectStore, StoreError};

/// Budget-metered access to ground truth.
pub struct Oracle {
    labels: Vec<u8>,
    revealed: AtomicU64,
}

#[derive(Debug, thiserror::Error)]
pub enum OracleError {
    #[error("labels object missing: {0}")]
    Missing(#[from] StoreError),
    #[error("labels.json malformed: {0}")]
    Malformed(String),
}

impl Oracle {
    /// Load labels.json from `{bucket}/labels.json`.
    pub fn load(store: &Arc<dyn ObjectStore>, bucket: &str) -> Result<Oracle, OracleError> {
        let raw = store.get(&format!("{bucket}/labels.json"))?;
        let text =
            std::str::from_utf8(&raw).map_err(|e| OracleError::Malformed(e.to_string()))?;
        let v = crate::json::parse(text).map_err(|e| OracleError::Malformed(e.to_string()))?;
        let arr = v
            .get("labels")
            .and_then(Value::as_array)
            .ok_or_else(|| OracleError::Malformed("missing 'labels' array".into()))?;
        let labels = arr
            .iter()
            .map(|x| {
                x.as_usize()
                    .and_then(|u| u8::try_from(u).ok())
                    .ok_or_else(|| OracleError::Malformed("label out of range".into()))
            })
            .collect::<Result<Vec<u8>, _>>()?;
        Ok(Oracle { labels, revealed: AtomicU64::new(0) })
    }

    /// Build directly from a label vector (tests, in-memory experiments).
    pub fn from_labels(labels: Vec<u8>) -> Oracle {
        Oracle { labels, revealed: AtomicU64::new(0) }
    }

    /// "Send to human annotators": reveal labels for sample ids, paying
    /// one budget unit each.
    pub fn label(&self, ids: &[u32]) -> Vec<u8> {
        self.revealed.fetch_add(ids.len() as u64, Ordering::Relaxed);
        ids.iter().map(|&i| self.labels[i as usize]).collect()
    }

    /// Labels revealed so far (= labeling budget consumed).
    pub fn budget_spent(&self) -> u64 {
        self.revealed.load(Ordering::Relaxed)
    }

    /// Evaluation-only access (test-set accuracy): does NOT count against
    /// the labeling budget — the paper's test sets are pre-labeled.
    pub fn eval_labels(&self, ids: &[u32]) -> Vec<u8> {
        ids.iter().map(|&i| self.labels[i as usize]).collect()
    }

    pub fn total(&self) -> usize {
        self.labels.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::MemStore;

    #[test]
    fn label_meters_budget_eval_does_not() {
        let o = Oracle::from_labels(vec![0, 1, 2, 3, 4]);
        assert_eq!(o.label(&[1, 3]), vec![1, 3]);
        assert_eq!(o.budget_spent(), 2);
        assert_eq!(o.eval_labels(&[0, 4]), vec![0, 4]);
        assert_eq!(o.budget_spent(), 2, "eval must not consume budget");
        o.label(&[0]);
        assert_eq!(o.budget_spent(), 3);
    }

    #[test]
    fn load_from_store() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        store.put("ds/labels.json", br#"{"labels": [3, 1, 4, 1, 5]}"#).unwrap();
        let o = Oracle::load(&store, "ds").unwrap();
        assert_eq!(o.total(), 5);
        assert_eq!(o.label(&[2]), vec![4]);
    }

    #[test]
    fn malformed_labels_rejected() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        store.put("a/labels.json", b"{}").unwrap();
        assert!(matches!(Oracle::load(&store, "a"), Err(OracleError::Malformed(_))));
        store.put("b/labels.json", br#"{"labels": [999]}"#).unwrap();
        assert!(matches!(Oracle::load(&store, "b"), Err(OracleError::Malformed(_))));
        assert!(matches!(Oracle::load(&store, "missing"), Err(OracleError::Missing(_))));
    }
}

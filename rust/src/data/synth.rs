//! Class-conditional synthetic image generator.
//!
//! Generative process per sample of class `c`:
//!   1. pick one of `clusters_per_class` sub-cluster templates of `c`
//!      (fixed random sparse patterns, amplitude `cluster_amp`);
//!   2. image = class_bias(c) * class_sep + template + noise * N(0, 1);
//!   3. with probability `redundancy`, instead emit a near-duplicate of a
//!      previously generated pool sample (tiny perturbation) — the
//!      redundancy diversity strategies exploit;
//!   4. clip to [-0.5, 0.5], quantize to u8.
//!
//! The class bias is the same repeat-one-hot pattern the python model test
//! uses, which is known (tested) to give linearly separable trunk
//! embeddings at sep >= 0.6 and overlapping ones below.

use std::sync::Arc;

use crate::data::image::{encode_image, IMG_DIM};
use crate::json::{Map, Value};
use crate::store::{Manifest, ObjectStore, SampleRef};
use crate::util::rng::Rng;

/// Everything that defines a synthetic dataset. Presets: [`DatasetSpec::cifarsim`],
/// [`DatasetSpec::svhnsim`].
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: String,
    pub seed: u64,
    pub num_classes: usize,
    pub n_init: usize,
    pub n_pool: usize,
    pub n_test: usize,
    /// Strength of the linear class signal (0.6 ~ separable, 0.4 ~ hard).
    pub class_sep: f32,
    /// Per-pixel gaussian noise sigma.
    pub noise: f32,
    /// Sub-clusters per class (diversity structure).
    pub clusters_per_class: usize,
    /// Amplitude of sub-cluster templates.
    pub cluster_amp: f32,
    /// Fraction of pool samples that are near-duplicates of earlier ones.
    pub redundancy: f32,
    /// 0 = balanced classes; > 0 = geometric decay of class frequency
    /// (class k has weight (1-imbalance)^k).
    pub imbalance: f32,
}

impl DatasetSpec {
    /// CIFAR-10 stand-in: balanced, separable, redundant pool.
    pub fn cifarsim(seed: u64) -> Self {
        DatasetSpec {
            name: "cifarsim".into(),
            seed,
            num_classes: 10,
            n_init: 1000,
            n_pool: 4000,
            n_test: 1000,
            class_sep: 0.55,
            noise: 0.15,
            clusters_per_class: 3,
            cluster_amp: 0.25,
            redundancy: 0.30,
            imbalance: 0.0,
        }
    }

    /// SVHN stand-in: imbalanced (digit frequencies), heavier overlap,
    /// more redundancy (street numbers repeat).
    pub fn svhnsim(seed: u64) -> Self {
        DatasetSpec {
            name: "svhnsim".into(),
            seed,
            num_classes: 10,
            n_init: 1000,
            n_pool: 4000,
            n_test: 1000,
            class_sep: 0.45,
            noise: 0.22,
            clusters_per_class: 2,
            cluster_amp: 0.18,
            redundancy: 0.45,
            imbalance: 0.12,
        }
    }

    /// Scale split sizes (benchmarks use bigger pools).
    pub fn with_sizes(mut self, n_init: usize, n_pool: usize, n_test: usize) -> Self {
        self.n_init = n_init;
        self.n_pool = n_pool;
        self.n_test = n_test;
        self
    }
}

/// The raw generated dataset, before it is written anywhere.
pub struct Generated {
    pub images: Vec<Vec<u8>>,
    pub labels: Vec<u8>,
    /// Split boundaries: [0, n_init) init, [n_init, n_init+n_pool) pool, rest test.
    pub n_init: usize,
    pub n_pool: usize,
}

/// Class-bias pattern: repeat-one-hot over the pixel vector.
fn class_bias(class: usize, num_classes: usize, sep: f32, out: &mut [f32]) {
    let rep = IMG_DIM.div_ceil(num_classes);
    let start = class * rep;
    let end = ((class + 1) * rep).min(IMG_DIM);
    for i in start..end {
        out[i] += sep;
    }
}

fn sample_class(rng: &mut Rng, num_classes: usize, imbalance: f32) -> usize {
    if imbalance <= 0.0 {
        return rng.below(num_classes);
    }
    // geometric weights (1-imb)^k, normalized by inverse-CDF sampling
    let q = 1.0 - imbalance as f64;
    let weights: Vec<f64> = (0..num_classes).map(|k| q.powi(k as i32)).collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.f64() * total;
    for (k, w) in weights.iter().enumerate() {
        if u < *w {
            return k;
        }
        u -= w;
    }
    num_classes - 1
}

/// Generate the full dataset in memory.
pub fn generate(spec: &DatasetSpec) -> Generated {
    assert!(spec.num_classes >= 2, "need >= 2 classes");
    let mut rng = Rng::new(spec.seed);

    // Fixed sub-cluster templates: sparse +-amp patterns.
    let mut templates: Vec<Vec<f32>> =
        Vec::with_capacity(spec.num_classes * spec.clusters_per_class);
    for _class in 0..spec.num_classes {
        for _k in 0..spec.clusters_per_class {
            let mut t = vec![0.0f32; IMG_DIM];
            // ~12% of pixels carry the template
            let n_active = IMG_DIM / 8;
            for _ in 0..n_active {
                let i = rng.below(IMG_DIM);
                t[i] += if rng.below(2) == 0 { spec.cluster_amp } else { -spec.cluster_amp };
            }
            templates.push(t);
        }
    }

    let total = spec.n_init + spec.n_pool + spec.n_test;
    let mut images: Vec<Vec<u8>> = Vec::with_capacity(total);
    let mut labels: Vec<u8> = Vec::with_capacity(total);
    // Indices of already-generated *pool* samples, for redundancy cloning.
    let pool_range = spec.n_init..spec.n_init + spec.n_pool;

    for i in 0..total {
        let in_pool = pool_range.contains(&i);
        let clone_from = if in_pool
            && !images.is_empty()
            && i > pool_range.start
            && (rng.f32() as f64) < spec.redundancy as f64
        {
            // near-duplicate of an earlier pool sample
            let lo = pool_range.start;
            Some(lo + rng.below(i - lo))
        } else {
            None
        };

        if let Some(src) = clone_from {
            let mut px: Vec<f32> =
                images[src].iter().map(|&b| b as f32 / 255.0 - 0.5).collect();
            for p in px.iter_mut() {
                *p += 0.01 * rng.normal_f32();
                *p = p.clamp(-0.5, 0.5);
            }
            images.push(encode_image(&px));
            labels.push(labels[src]);
            continue;
        }

        let class = sample_class(&mut rng, spec.num_classes, spec.imbalance);
        let k = rng.below(spec.clusters_per_class);
        let template = &templates[class * spec.clusters_per_class + k];
        let mut px = vec![0.0f32; IMG_DIM];
        class_bias(class, spec.num_classes, spec.class_sep, &mut px);
        for (p, t) in px.iter_mut().zip(template) {
            *p += t + spec.noise * rng.normal_f32();
            *p = p.clamp(-0.5, 0.5);
        }
        images.push(encode_image(&px));
        labels.push(class as u8);
    }

    Generated { images, labels, n_init: spec.n_init, n_pool: spec.n_pool }
}

/// Generate and write into an object store under `bucket`, returning the
/// manifest. Layout:
///   {bucket}/{split}/img_{id:06}.bin   sample blobs
///   {bucket}/labels.json               oracle-only ground truth
///   {bucket}/manifest.json             the returned manifest
/// `uri_scheme` ("mem" | "s3sim") prefixes the sample URIs.
pub fn generate_into_store(
    spec: &DatasetSpec,
    store: &Arc<dyn ObjectStore>,
    uri_scheme: &str,
    bucket: &str,
) -> Manifest {
    let gen = generate(spec);
    let splits = [
        ("init", 0, gen.n_init),
        ("pool", gen.n_init, gen.n_init + gen.n_pool),
        ("test", gen.n_init + gen.n_pool, gen.images.len()),
    ];

    let mut refs: Vec<Vec<SampleRef>> = vec![vec![], vec![], vec![]];
    for (si, (split, lo, hi)) in splits.iter().enumerate() {
        for id in *lo..*hi {
            let key = format!("{bucket}/{split}/img_{id:06}.bin");
            store.put(&key, &gen.images[id]).expect("store put");
            refs[si].push(SampleRef {
                id: id as u32,
                uri: format!("{uri_scheme}://{key}"),
            });
        }
    }

    // labels.json — oracle side-channel, not part of the manifest.
    let mut lm = Map::new();
    lm.insert(
        "labels",
        Value::Array(gen.labels.iter().map(|&l| Value::from(l as u64)).collect()),
    );
    store
        .put(&format!("{bucket}/labels.json"), crate::json::to_string(&Value::Object(lm)).as_bytes())
        .expect("store labels");

    let manifest = Manifest {
        name: spec.name.clone(),
        num_classes: spec.num_classes,
        img_dim: IMG_DIM,
        init: refs.remove(0),
        pool: refs.remove(0),
        test: refs.remove(0),
    };
    store
        .put(&format!("{bucket}/manifest.json"), manifest.to_json().as_bytes())
        .expect("store manifest");
    manifest
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::MemStore;

    fn tiny_spec() -> DatasetSpec {
        DatasetSpec::cifarsim(1).with_sizes(20, 50, 20)
    }

    #[test]
    fn deterministic_generation() {
        let a = generate(&tiny_spec());
        let b = generate(&tiny_spec());
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn different_seed_different_data() {
        let a = generate(&tiny_spec());
        let mut spec = tiny_spec();
        spec.seed = 2;
        let b = generate(&spec);
        assert_ne!(a.images, b.images);
    }

    #[test]
    fn sizes_and_label_range() {
        let g = generate(&tiny_spec());
        assert_eq!(g.images.len(), 90);
        assert_eq!(g.labels.len(), 90);
        assert!(g.labels.iter().all(|&l| (l as usize) < 10));
        assert!(g.images.iter().all(|img| img.len() == IMG_DIM));
    }

    #[test]
    fn balanced_vs_imbalanced_class_histogram() {
        let mut spec = DatasetSpec::cifarsim(3).with_sizes(0, 3000, 0);
        spec.redundancy = 0.0;
        let g = generate(&spec);
        let mut hist = [0usize; 10];
        for &l in &g.labels {
            hist[l as usize] += 1;
        }
        let (min, max) = (hist.iter().min().unwrap(), hist.iter().max().unwrap());
        assert!(*max < min * 2, "balanced spec too skewed: {hist:?}");

        let mut spec = DatasetSpec::svhnsim(3).with_sizes(0, 3000, 0);
        spec.redundancy = 0.0;
        let g = generate(&spec);
        let mut hist = [0usize; 10];
        for &l in &g.labels {
            hist[l as usize] += 1;
        }
        assert!(
            hist[0] > hist[9] * 2,
            "imbalanced spec not skewed enough: {hist:?}"
        );
    }

    #[test]
    fn redundancy_produces_near_duplicates() {
        let mut spec = tiny_spec().with_sizes(0, 200, 0);
        spec.redundancy = 0.5;
        let g = generate(&spec);
        // Count pool samples whose nearest neighbour is very close.
        let mut dup = 0;
        for i in 1..g.images.len() {
            for j in 0..i {
                let d: f64 = g.images[i]
                    .iter()
                    .zip(&g.images[j])
                    .map(|(&a, &b)| {
                        let x = a as f64 - b as f64;
                        x * x
                    })
                    .sum::<f64>()
                    / IMG_DIM as f64;
                if d < 20.0 {
                    dup += 1;
                    break;
                }
            }
        }
        assert!(dup > 40, "expected many near-duplicates, got {dup}");
    }

    #[test]
    fn store_layout_and_manifest() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
        let m = generate_into_store(&tiny_spec(), &store, "mem", "ds1");
        assert_eq!(m.init.len(), 20);
        assert_eq!(m.pool.len(), 50);
        assert_eq!(m.test.len(), 20);
        assert!(store.exists("ds1/labels.json"));
        assert!(store.exists("ds1/manifest.json"));
        // every manifest uri resolves
        for s in m.init.iter().chain(&m.pool).chain(&m.test) {
            let uri = crate::uri::Uri::parse(&s.uri).unwrap();
            let key = format!("{}/{}", uri.bucket, uri.key);
            assert!(store.exists(&key), "missing {key}");
        }
        // manifest on disk parses back
        let on_disk =
            Manifest::from_json(std::str::from_utf8(&store.get("ds1/manifest.json").unwrap()).unwrap())
                .unwrap();
        assert_eq!(on_disk, m);
    }
}

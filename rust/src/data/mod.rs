//! Synthetic datasets — the CIFAR-10 / SVHN substitution (DESIGN.md).
//!
//! The generator produces class-conditional 32x32x3 u8 images with the
//! structure the paper's experiments depend on:
//!
//! * a *class signal* the fixed trunk can embed separably (accuracy climbs
//!   with labeled data — Fig 4a/5a);
//! * *sub-clusters* per class plus *near-duplicate redundancy* so
//!   diversity-based strategies (Core-Set, KCG, DBAL) have something to
//!   exploit over pure uncertainty sampling;
//! * optional *class imbalance* and heavier overlap ("svhnsim") so the two
//!   datasets prefer different strategies — the premise of Fig 5b.
//!
//! Everything is a pure function of the spec's seed: runs replay exactly.

mod image;
mod oracle;
mod synth;

pub use image::{decode_image, encode_image, IMG_BYTES, IMG_DIM};
pub use oracle::Oracle;
pub use synth::{generate, generate_into_store, DatasetSpec, Generated};

//! Artifact index: the Rust view of `artifacts/manifest.json`.
//!
//! aot.py emits one HLO-text artifact per (entry point, static shape)
//! variant plus a manifest describing inputs/outputs. This module parses
//! that manifest and answers "which artifact serves a batch of n?" — the
//! dynamic batcher pads batches up to the chosen variant.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::json::{self, Value};

/// Input spec of one artifact parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One compiled entry point.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
}

/// Model geometry shared between python and rust (manifest `model` block).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelGeometry {
    pub img_dim: usize,
    pub embed_dim: usize,
    pub num_classes: usize,
    pub batch_variants: Vec<usize>,
    pub dist_tile: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
}

/// Parsed manifest + artifact directory.
#[derive(Debug, Clone)]
pub struct ArtifactIndex {
    dir: PathBuf,
    pub model: ModelGeometry,
    artifacts: BTreeMap<String, ArtifactSpec>,
}

#[derive(Debug, thiserror::Error)]
pub enum ArtifactError {
    #[error("cannot read {path}: {source}")]
    Io {
        path: String,
        #[source]
        source: std::io::Error,
    },
    #[error("manifest malformed: {0}")]
    Malformed(String),
    #[error("unknown artifact '{0}' (is `make artifacts` up to date?)")]
    Unknown(String),
    #[error("no batch variant >= {0} compiled (max is {1})")]
    BatchTooLarge(usize, usize),
}

impl ArtifactIndex {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactIndex, ArtifactError> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| ArtifactError::Io {
            path: path.display().to_string(),
            source: e,
        })?;
        Self::from_json(&text, dir)
    }

    /// Parse manifest text (dir is where artifact files live).
    pub fn from_json(text: &str, dir: PathBuf) -> Result<ArtifactIndex, ArtifactError> {
        let v = json::parse(text).map_err(|e| ArtifactError::Malformed(e.to_string()))?;
        let m = v
            .get("model")
            .ok_or_else(|| ArtifactError::Malformed("missing 'model'".into()))?;
        let geom = ModelGeometry {
            img_dim: req_usize(m, "img_dim")?,
            embed_dim: req_usize(m, "embed_dim")?,
            num_classes: req_usize(m, "num_classes")?,
            batch_variants: m
                .get("batch_variants")
                .and_then(Value::as_array)
                .ok_or_else(|| ArtifactError::Malformed("missing batch_variants".into()))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| ArtifactError::Malformed("bad variant".into())))
                .collect::<Result<Vec<_>, _>>()?,
            dist_tile: req_usize(m, "dist_tile")?,
            train_batch: req_usize(m, "train_batch")?,
            eval_batch: req_usize(m, "eval_batch")?,
        };
        if geom.batch_variants.is_empty() {
            return Err(ArtifactError::Malformed("empty batch_variants".into()));
        }
        let mut variants = geom.batch_variants.clone();
        variants.sort_unstable();
        if variants != geom.batch_variants {
            return Err(ArtifactError::Malformed("batch_variants not sorted".into()));
        }

        let arts = v
            .get("artifacts")
            .and_then(Value::as_object)
            .ok_or_else(|| ArtifactError::Malformed("missing 'artifacts'".into()))?;
        let mut artifacts = BTreeMap::new();
        for (name, spec) in arts.iter() {
            let file = spec
                .get("file")
                .and_then(Value::as_str)
                .ok_or_else(|| ArtifactError::Malformed(format!("{name}: missing file")))?
                .to_string();
            let inputs = spec
                .get("inputs")
                .and_then(Value::as_array)
                .ok_or_else(|| ArtifactError::Malformed(format!("{name}: missing inputs")))?
                .iter()
                .map(|i| {
                    let iname = i
                        .get("name")
                        .and_then(Value::as_str)
                        .ok_or_else(|| ArtifactError::Malformed(format!("{name}: input name")))?;
                    let shape = i
                        .get("shape")
                        .and_then(Value::as_array)
                        .ok_or_else(|| ArtifactError::Malformed(format!("{name}: input shape")))?
                        .iter()
                        .map(|d| {
                            d.as_usize()
                                .ok_or_else(|| ArtifactError::Malformed(format!("{name}: dim")))
                        })
                        .collect::<Result<Vec<_>, _>>()?;
                    Ok(InputSpec { name: iname.to_string(), shape })
                })
                .collect::<Result<Vec<_>, ArtifactError>>()?;
            let outputs = spec
                .get("outputs")
                .and_then(Value::as_array)
                .ok_or_else(|| ArtifactError::Malformed(format!("{name}: missing outputs")))?
                .iter()
                .map(|o| {
                    o.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| ArtifactError::Malformed(format!("{name}: output")))
                })
                .collect::<Result<Vec<_>, _>>()?;
            artifacts.insert(
                name.to_string(),
                ArtifactSpec { name: name.to_string(), file, inputs, outputs },
            );
        }
        Ok(ArtifactIndex { dir, model: geom, artifacts })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec, ArtifactError> {
        self.artifacts.get(name).ok_or_else(|| ArtifactError::Unknown(name.to_string()))
    }

    /// Absolute path of an artifact's HLO text.
    pub fn path_of(&self, name: &str) -> Result<PathBuf, ArtifactError> {
        Ok(self.dir.join(&self.get(name)?.file))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.artifacts.keys().map(String::as_str)
    }

    /// Smallest compiled batch variant that fits `n` samples.
    pub fn batch_variant_for(&self, n: usize) -> Result<usize, ArtifactError> {
        let max = *self.model.batch_variants.last().unwrap();
        self.model
            .batch_variants
            .iter()
            .copied()
            .find(|&v| v >= n)
            .ok_or(ArtifactError::BatchTooLarge(n, max))
    }

    /// Largest compiled batch variant (the serving chunk size).
    pub fn max_batch(&self) -> usize {
        *self.model.batch_variants.last().unwrap()
    }

    /// Entry-point name for a batched artifact, e.g. `("forward", 16)`.
    pub fn batched_name(&self, entry: &str, batch: usize) -> String {
        format!("{entry}_b{batch}")
    }
}

fn req_usize(v: &Value, field: &str) -> Result<usize, ArtifactError> {
    v.get(field)
        .and_then(Value::as_usize)
        .ok_or_else(|| ArtifactError::Malformed(format!("missing/invalid '{field}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) const MINI_MANIFEST: &str = r#"{
      "format": "hlo-text/return-tuple",
      "model": {
        "img_dim": 3072, "embed_dim": 64, "num_classes": 10,
        "batch_variants": [1, 2, 4, 8, 16, 32, 64, 128],
        "dist_tile": 256, "train_batch": 64, "eval_batch": 256
      },
      "artifacts": {
        "forward_b16": {
          "file": "forward_b16.hlo.txt",
          "sha256": "x",
          "inputs": [
            {"name": "images", "shape": [16, 3072], "dtype": "f32"},
            {"name": "w", "shape": [64, 10], "dtype": "f32"},
            {"name": "b", "shape": [10], "dtype": "f32"}
          ],
          "outputs": ["embeddings", "scores"]
        }
      }
    }"#;

    #[test]
    fn parses_manifest() {
        let idx = ArtifactIndex::from_json(MINI_MANIFEST, PathBuf::from("/a")).unwrap();
        assert_eq!(idx.model.img_dim, 3072);
        assert_eq!(idx.model.num_classes, 10);
        let spec = idx.get("forward_b16").unwrap();
        assert_eq!(spec.inputs.len(), 3);
        assert_eq!(spec.inputs[0].shape, vec![16, 3072]);
        assert_eq!(spec.outputs, vec!["embeddings", "scores"]);
        assert_eq!(idx.path_of("forward_b16").unwrap(), PathBuf::from("/a/forward_b16.hlo.txt"));
    }

    #[test]
    fn batch_variant_selection() {
        let idx = ArtifactIndex::from_json(MINI_MANIFEST, PathBuf::from("/a")).unwrap();
        assert_eq!(idx.batch_variant_for(1).unwrap(), 1);
        assert_eq!(idx.batch_variant_for(3).unwrap(), 4);
        assert_eq!(idx.batch_variant_for(16).unwrap(), 16);
        assert_eq!(idx.batch_variant_for(100).unwrap(), 128);
        assert!(matches!(
            idx.batch_variant_for(129),
            Err(ArtifactError::BatchTooLarge(129, 128))
        ));
        assert_eq!(idx.max_batch(), 128);
    }

    #[test]
    fn unknown_artifact_and_malformed() {
        let idx = ArtifactIndex::from_json(MINI_MANIFEST, PathBuf::from("/a")).unwrap();
        assert!(matches!(idx.get("nope"), Err(ArtifactError::Unknown(_))));
        assert!(ArtifactIndex::from_json("{}", PathBuf::from("/a")).is_err());
        assert!(ArtifactIndex::from_json("not json", PathBuf::from("/a")).is_err());
    }

    #[test]
    fn loads_real_manifest_when_built() {
        // Runs against the actual `make artifacts` output when present.
        let Some(dir) = crate::runtime::find_artifacts_dir(None) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let idx = ArtifactIndex::load(&dir).unwrap();
        assert_eq!(idx.model.img_dim, 3072);
        for bs in &idx.model.batch_variants {
            for ep in ["embed", "forward", "scores"] {
                let name = idx.batched_name(ep, *bs);
                assert!(idx.get(&name).is_ok(), "missing {name}");
                assert!(idx.path_of(&name).unwrap().exists(), "file missing for {name}");
            }
        }
        assert!(idx.get("train_step").is_ok());
    }
}

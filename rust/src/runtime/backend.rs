//! `ComputeBackend`: the semantic compute contract of the system, and
//! `HostBackend`, its pure-Rust reference implementation.
//!
//! Everything above the runtime (strategies, trainer, pipeline, agent)
//! talks to this trait, so the whole coordinator runs identically against:
//!
//! * [`HostBackend`] — straight-line Rust math. The scores / sqdist /
//!   train_step / eval_logits implementations mirror
//!   `python/compile/kernels/ref.py` and `model.py` exactly (the
//!   integration tests cross-check them against the PJRT artifacts). Its
//!   `embed` is a *stand-in trunk* (fixed random projection + layernorm),
//!   deterministic but intentionally NOT the JAX trunk — tests that need
//!   trunk-faithful embeddings use `PjrtBackend`.
//! * [`super::PjrtBackend`] — the AOT artifacts through PJRT (production).

use crate::util::mat::Mat;

/// Runtime failure surface shared by backends.
#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    #[error("shape mismatch: {0}")]
    Shape(String),
    #[error("artifact error: {0}")]
    Artifact(#[from] super::artifact::ArtifactError),
    #[error("xla error: {0}")]
    Xla(String),
    #[error("worker pool unavailable: {0}")]
    Pool(String),
}

pub type RtResult<T> = Result<T, RuntimeError>;

/// Number of uncertainty score columns (see kernels/ref.py::SCORE_NAMES).
pub const NUM_SCORES: usize = 4;

/// The compute contract (shapes in docs; all f32).
pub trait ComputeBackend: Send + Sync {
    /// Trunk forward: `[B, img_dim] -> [B, embed_dim]`.
    fn embed(&self, images: &Mat) -> RtResult<Mat>;

    /// Serving hot path: images + head `(w: [D, C], b: [C])` ->
    /// `([B, D] embeddings, [B, 4] scores)`.
    fn forward(&self, images: &Mat, w: &Mat, b: &[f32]) -> RtResult<(Mat, Mat)>;

    /// Fused uncertainty scores: `[B, C] logits -> [B, 4]`.
    fn scores(&self, logits: &Mat) -> RtResult<Mat>;

    /// Pairwise squared distances: `[M, D], [N, D] -> [M, N]`.
    fn sqdist(&self, x: &Mat, y: &Mat) -> RtResult<Mat>;

    /// One last-layer SGD step on `(w, b)` over a minibatch of embeddings;
    /// zero one-hot rows are inert padding. Returns the (mean) loss.
    fn train_step(
        &self,
        w: &mut Mat,
        b: &mut [f32],
        x: &Mat,
        y_onehot: &Mat,
        lr: f32,
    ) -> RtResult<f32>;

    /// Evaluation logits: `[B, D] x (w, b) -> [B, C]`.
    fn eval_logits(&self, x: &Mat, w: &Mat, b: &[f32]) -> RtResult<Mat>;

    /// Backend tag for metrics/logs.
    fn name(&self) -> &'static str;

    /// Pre-compile / pre-warm the serving path for a given inference
    /// batch size. No-op by default (host backend); the PJRT backend
    /// compiles the serving artifact variants on every replica so the
    /// first request doesn't pay XLA compile time (§Perf).
    fn warmup_serving(&self, _batch_size: usize) -> RtResult<()> {
        Ok(())
    }
}

/// Pure-Rust reference backend.
pub struct HostBackend {
    embed_dim: usize,
    img_dim: usize,
    /// Fixed random projection (the mock trunk), stored *transposed*
    /// (`[embed_dim, img_dim]` row-major) so `embed`'s inner product walks
    /// contiguous memory — generated in the original `[img_dim,
    /// embed_dim]` order first, so the values match earlier builds
    /// exactly.
    proj_t: Mat,
}

impl HostBackend {
    /// `img_dim`/`embed_dim` default to the canonical model geometry.
    pub fn new() -> Self {
        Self::with_dims(3072, 64)
    }

    pub fn with_dims(img_dim: usize, embed_dim: usize) -> Self {
        let mut rng = crate::util::rng::Rng::new(0x7777_2022);
        let scale = (1.0 / img_dim as f64).sqrt() as f32;
        let data: Vec<f32> =
            (0..img_dim * embed_dim).map(|_| scale * rng.normal_f32()).collect();
        let proj = Mat::from_vec(data, img_dim, embed_dim);
        HostBackend { embed_dim, img_dim, proj_t: proj.transposed() }
    }
}

impl Default for HostBackend {
    fn default() -> Self {
        Self::new()
    }
}

/// Row-wise softmax + the four uncertainty scores (mirrors ref.py).
pub fn host_scores(logits: &Mat) -> Mat {
    let (b, c) = logits.shape();
    let mut out = Mat::zeros(b, NUM_SCORES);
    let mut p = vec![0.0f32; c];
    for i in 0..b {
        let row = logits.row(i);
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for (j, &l) in row.iter().enumerate() {
            let e = (l - m).exp();
            p[j] = e;
            z += e;
        }
        let mut p1 = 0.0f32;
        let mut p2 = 0.0f32;
        let mut entropy = 0.0f32;
        for pj in p.iter_mut() {
            *pj /= z;
            let v = *pj;
            if v > p1 {
                p2 = p1;
                p1 = v;
            } else if v > p2 {
                p2 = v;
            }
            if v > 0.0 {
                entropy -= v * v.ln();
            }
        }
        let r = out.row_mut(i);
        r[0] = 1.0 - p1; // least confidence
        r[1] = p1 - p2; // margin
        r[2] = if p1 > 0.0 { p2 / p1 } else { 1.0 }; // ratio
        r[3] = entropy;
    }
    out
}

/// Blocked pairwise squared distance (mirrors ref.py, clamped at 0).
pub fn host_sqdist(x: &Mat, y: &Mat) -> RtResult<Mat> {
    if x.cols() != y.cols() {
        return Err(RuntimeError::Shape(format!(
            "sqdist dims differ: {} vs {}",
            x.cols(),
            y.cols()
        )));
    }
    let (m, d) = x.shape();
    let n = y.rows();
    let xx: Vec<f32> = (0..m).map(|i| x.row(i).iter().map(|v| v * v).sum()).collect();
    let yy: Vec<f32> = (0..n).map(|j| y.row(j).iter().map(|v| v * v).sum()).collect();
    let mut out = Mat::zeros(m, n);
    for i in 0..m {
        let xi = x.row(i);
        let row = out.row_mut(i);
        for j in 0..n {
            let yj = y.row(j);
            let mut cross = 0.0f32;
            for k in 0..d {
                cross += xi[k] * yj[k];
            }
            row[j] = (xx[i] + yy[j] - 2.0 * cross).max(0.0);
        }
    }
    Ok(out)
}

/// One softmax-xent SGD step (mirrors model.py::train_step, including the
/// inert-padding convention: rows with all-zero one-hot contribute nothing
/// and the loss normalizes by the number of real rows).
pub fn host_train_step(
    w: &mut Mat,
    b: &mut [f32],
    x: &Mat,
    y_onehot: &Mat,
    lr: f32,
) -> RtResult<f32> {
    let (n, d) = x.shape();
    let c = w.cols();
    if w.rows() != d || y_onehot.shape() != (n, c) || b.len() != c {
        return Err(RuntimeError::Shape(format!(
            "train_step: x{:?} w{:?} y{:?} b[{}]",
            x.shape(),
            w.shape(),
            y_onehot.shape(),
            b.len()
        )));
    }
    let n_real: f32 = y_onehot.as_slice().iter().sum::<f32>().max(1.0);

    let mut gw = Mat::zeros(d, c);
    let mut gb = vec![0.0f32; c];
    let mut loss = 0.0f32;
    let mut p = vec![0.0f32; c];
    for i in 0..n {
        let xi = x.row(i);
        let yi = y_onehot.row(i);
        let is_pad = yi.iter().all(|&v| v == 0.0);
        // logits
        let m = {
            let mut m = f32::NEG_INFINITY;
            for j in 0..c {
                let mut l = b[j];
                for k in 0..d {
                    l += xi[k] * w.get(k, j);
                }
                p[j] = l;
                m = m.max(l);
            }
            m
        };
        let mut z = 0.0f32;
        for pj in p.iter_mut() {
            *pj = (*pj - m).exp();
            z += *pj;
        }
        for (j, pj) in p.iter_mut().enumerate() {
            *pj /= z;
            if yi[j] > 0.0 {
                loss -= yi[j] * pj.max(1e-30).ln();
            }
        }
        if is_pad {
            continue;
        }
        // grad: (p - y) / n_real
        for j in 0..c {
            let g = (p[j] - yi[j]) / n_real;
            gb[j] += g;
            for k in 0..d {
                *gw.row_mut(k).get_mut(j).unwrap() += xi[k] * g;
            }
        }
    }
    for k in 0..d {
        for j in 0..c {
            let v = w.get(k, j) - lr * gw.get(k, j);
            w.set(k, j, v);
        }
    }
    for j in 0..c {
        b[j] -= lr * gb[j];
    }
    Ok(loss / n_real)
}

/// `x @ w + b` with `wt = w` transposed (`[C, D]` row-major): the inner
/// k-loop reads `xi` and `wt.row(j)` contiguously instead of striding
/// `w` by `cols` per element. The per-output summation order (bias first,
/// then k ascending) is identical to the naive `x @ w` loop, so results
/// are bit-exact with it.
fn eval_logits_wt(x: &Mat, wt: &Mat, b: &[f32]) -> Mat {
    let (n, d) = x.shape();
    let c = wt.rows();
    debug_assert_eq!(wt.cols(), d);
    debug_assert_eq!(b.len(), c);
    let mut out = Mat::zeros(n, c);
    for i in 0..n {
        let xi = x.row(i);
        let row = out.row_mut(i);
        for j in 0..c {
            let wj = wt.row(j);
            let mut l = b[j];
            for k in 0..d {
                l += xi[k] * wj[k];
            }
            row[j] = l;
        }
    }
    out
}

/// `x @ w + b` (mirrors model.py::eval_logits). Hoists one transposed
/// copy of `w` so the hot inner loop is cache-friendly (§Perf); see
/// `eval_logits_wt` for the bit-exactness argument.
pub fn host_eval_logits(x: &Mat, w: &Mat, b: &[f32]) -> RtResult<Mat> {
    let (_, d) = x.shape();
    let c = w.cols();
    if w.rows() != d || b.len() != c {
        return Err(RuntimeError::Shape(format!(
            "eval_logits: x{:?} w{:?} b[{}]",
            x.shape(),
            w.shape(),
            b.len()
        )));
    }
    let wt = w.transposed();
    Ok(eval_logits_wt(x, &wt, b))
}

impl ComputeBackend for HostBackend {
    fn embed(&self, images: &Mat) -> RtResult<Mat> {
        if images.cols() != self.img_dim {
            return Err(RuntimeError::Shape(format!(
                "embed: images cols {} != img_dim {}",
                images.cols(),
                self.img_dim
            )));
        }
        // the projection is pre-transposed at construction, so the scan
        // hot path never pays the per-call transpose
        let mut e = eval_logits_wt(images, &self.proj_t, &vec![0.0; self.embed_dim]);
        // layernorm rows (like the trunk's output)
        for i in 0..e.rows() {
            let row = e.row_mut(i);
            let mean = row.iter().sum::<f32>() / row.len() as f32;
            let var =
                row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / row.len() as f32;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for v in row.iter_mut() {
                *v = (*v - mean) * inv;
            }
        }
        Ok(e)
    }

    fn forward(&self, images: &Mat, w: &Mat, b: &[f32]) -> RtResult<(Mat, Mat)> {
        let e = self.embed(images)?;
        let logits = host_eval_logits(&e, w, b)?;
        Ok((e, host_scores(&logits)))
    }

    fn scores(&self, logits: &Mat) -> RtResult<Mat> {
        Ok(host_scores(logits))
    }

    fn sqdist(&self, x: &Mat, y: &Mat) -> RtResult<Mat> {
        host_sqdist(x, y)
    }

    fn train_step(
        &self,
        w: &mut Mat,
        b: &mut [f32],
        x: &Mat,
        y_onehot: &Mat,
        lr: f32,
    ) -> RtResult<f32> {
        host_train_step(w, b, x, y_onehot, lr)
    }

    fn eval_logits(&self, x: &Mat, w: &Mat, b: &[f32]) -> RtResult<Mat> {
        host_eval_logits(x, w, b)
    }

    fn name(&self) -> &'static str {
        "host"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::rng::Rng;

    fn rand_mat(rng: &mut Rng, r: usize, c: usize, scale: f32) -> Mat {
        Mat::from_vec((0..r * c).map(|_| scale * rng.normal_f32()).collect(), r, c)
    }

    #[test]
    fn scores_uniform_and_peaked_extremes() {
        let c = 10;
        let uniform = Mat::zeros(2, c);
        let s = host_scores(&uniform);
        assert!((s.get(0, 0) - (1.0 - 0.1)).abs() < 1e-6);
        assert!(s.get(0, 1).abs() < 1e-6);
        assert!((s.get(0, 2) - 1.0).abs() < 1e-6);
        assert!((s.get(0, 3) - (c as f32).ln()).abs() < 1e-5);

        let mut peaked = Mat::zeros(1, c);
        peaked.set(0, 3, 50.0);
        let s = host_scores(&peaked);
        assert!(s.get(0, 0) < 1e-6);
        assert!(s.get(0, 1) > 1.0 - 1e-6);
        assert!(s.get(0, 3) < 1e-4);
    }

    #[test]
    fn sqdist_hand_computed_and_properties() {
        let x = Mat::from_vec(vec![0.0, 0.0, 1.0, 1.0], 2, 2);
        let y = Mat::from_vec(vec![0.0, 1.0, 2.0, 0.0, 1.0, 1.0], 3, 2);
        let d = host_sqdist(&x, &y).unwrap();
        assert_eq!(d.row(0), &[1.0, 4.0, 2.0]);
        assert_eq!(d.row(1), &[1.0, 2.0, 0.0]);
        // mismatched dims
        assert!(host_sqdist(&x, &Mat::zeros(2, 3)).is_err());
    }

    #[test]
    fn prop_sqdist_symmetry_and_nonneg() {
        crate::util::prop::check("sqdist-props", 30, |rng| {
            let d = 1 + rng.below(16);
            let (rx, ry) = (1 + rng.below(20), 1 + rng.below(20));
            let x = rand_mat(rng, rx, d, 2.0);
            let y = rand_mat(rng, ry, d, 2.0);
            let dxy = host_sqdist(&x, &y).unwrap();
            let dyx = host_sqdist(&y, &x).unwrap();
            for i in 0..x.rows() {
                for j in 0..y.rows() {
                    prop_assert!(dxy.get(i, j) >= 0.0, "negative distance");
                    prop_assert!(
                        (dxy.get(i, j) - dyx.get(j, i)).abs() < 1e-3,
                        "asymmetric: {} vs {}",
                        dxy.get(i, j),
                        dyx.get(j, i)
                    );
                }
            }
            let dxx = host_sqdist(&x, &x).unwrap();
            for i in 0..x.rows() {
                prop_assert!(dxx.get(i, i) < 1e-3, "diag not ~0: {}", dxx.get(i, i));
            }
            Ok(())
        });
    }

    #[test]
    fn train_step_first_loss_is_log_c_and_descends() {
        let mut rng = Rng::new(5);
        let d = 16;
        let c = 10;
        let n = 64;
        let x = rand_mat(&mut rng, n, d, 1.0);
        let mut y = Mat::zeros(n, c);
        for i in 0..n {
            y.set(i, i % c, 1.0);
        }
        let mut w = Mat::zeros(d, c);
        let mut b = vec![0.0; c];
        let first = host_train_step(&mut w, &mut b, &x, &y, 0.5).unwrap();
        assert!((first - (c as f32).ln()).abs() < 1e-4, "first={first}");
        let mut last = first;
        for _ in 0..60 {
            last = host_train_step(&mut w, &mut b, &x, &y, 0.5).unwrap();
        }
        assert!(last < first * 0.8, "no descent: {first} -> {last}");
    }

    #[test]
    fn train_step_padding_rows_are_inert() {
        let mut rng = Rng::new(9);
        let d = 8;
        let c = 4;
        let x_real = rand_mat(&mut rng, 5, d, 1.0);
        let mut y_real = Mat::zeros(5, c);
        for i in 0..5 {
            y_real.set(i, i % c, 1.0);
        }
        // padded copies
        let x_pad = x_real.pad_rows_to(8);
        let y_pad = y_real.pad_rows_to(8);

        let mut w1 = Mat::zeros(d, c);
        let mut b1 = vec![0.0; c];
        let l1 = host_train_step(&mut w1, &mut b1, &x_real, &y_real, 0.3).unwrap();
        let mut w2 = Mat::zeros(d, c);
        let mut b2 = vec![0.0; c];
        let l2 = host_train_step(&mut w2, &mut b2, &x_pad, &y_pad, 0.3).unwrap();
        assert!((l1 - l2).abs() < 1e-6);
        for (a, b) in w1.as_slice().iter().zip(w2.as_slice()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    /// The pre-refactor `x @ w + b` loop, kept verbatim as the reference
    /// the cache-friendly kernel must match bit-for-bit.
    fn naive_eval_logits(x: &Mat, w: &Mat, b: &[f32]) -> Mat {
        let (n, d) = x.shape();
        let c = w.cols();
        let mut out = Mat::zeros(n, c);
        for i in 0..n {
            let xi = x.row(i);
            let row = out.row_mut(i);
            for j in 0..c {
                let mut l = b[j];
                for k in 0..d {
                    l += xi[k] * w.get(k, j);
                }
                row[j] = l;
            }
        }
        out
    }

    #[test]
    fn prop_eval_logits_bitexact_with_naive_reference() {
        crate::util::prop::check("eval-logits-transposed", 40, |rng| {
            let (n, d, c) = (1 + rng.below(17), 1 + rng.below(96), 1 + rng.below(12));
            let x = rand_mat(rng, n, d, 1.5);
            let w = rand_mat(rng, d, c, 0.8);
            let b: Vec<f32> = (0..c).map(|_| rng.normal_f32()).collect();
            let want = naive_eval_logits(&x, &w, &b);
            let got = host_eval_logits(&x, &w, &b).unwrap();
            crate::prop_assert!(got.shape() == want.shape(), "shape mismatch");
            for (a, e) in got.as_slice().iter().zip(want.as_slice()) {
                crate::prop_assert!(
                    a.to_bits() == e.to_bits(),
                    "not bit-exact: {a} vs {e}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn embed_matches_naive_projection_bitexact() {
        // HostBackend pre-transposes its projection; the layernormed
        // output must still equal the naive-projection path exactly
        let be = HostBackend::with_dims(48, 8);
        let mut rng = Rng::new(11);
        let img = rand_mat(&mut rng, 5, 48, 0.5);
        // rebuild the projection exactly as with_dims does
        let mut prng = crate::util::rng::Rng::new(0x7777_2022);
        let scale = (1.0 / 48f64).sqrt() as f32;
        let proj =
            Mat::from_vec((0..48 * 8).map(|_| scale * prng.normal_f32()).collect(), 48, 8);
        let mut want = naive_eval_logits(&img, &proj, &vec![0.0; 8]);
        for i in 0..want.rows() {
            let row = want.row_mut(i);
            let mean = row.iter().sum::<f32>() / row.len() as f32;
            let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
                / row.len() as f32;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for v in row.iter_mut() {
                *v = (*v - mean) * inv;
            }
        }
        let got = be.embed(&img).unwrap();
        for (a, e) in got.as_slice().iter().zip(want.as_slice()) {
            assert_eq!(a.to_bits(), e.to_bits(), "embed not bit-exact: {a} vs {e}");
        }
    }

    #[test]
    fn embed_is_deterministic_and_normalized() {
        let be = HostBackend::new();
        let mut rng = Rng::new(1);
        let img = rand_mat(&mut rng, 4, 3072, 0.3);
        let e1 = be.embed(&img).unwrap();
        let e2 = be.embed(&img).unwrap();
        assert_eq!(e1, e2);
        for i in 0..e1.rows() {
            let mean: f32 = e1.row(i).iter().sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-4);
        }
        // batch invariance: row 0 of batch == single forward
        let single = be.embed(&img.take_rows(1)).unwrap();
        for k in 0..64 {
            assert!((e1.get(0, k) - single.get(0, k)).abs() < 1e-5);
        }
    }

    #[test]
    fn forward_composes_embed_head_scores() {
        let be = HostBackend::new();
        let mut rng = Rng::new(2);
        let img = rand_mat(&mut rng, 3, 3072, 0.3);
        let w = Mat::zeros(64, 10);
        let b = vec![0.0; 10];
        let (e, s) = be.forward(&img, &w, &b).unwrap();
        assert_eq!(e.shape(), (3, 64));
        assert_eq!(s.shape(), (3, NUM_SCORES));
        // zero head -> uniform scores
        assert!((s.get(0, 3) - (10.0f32).ln()).abs() < 1e-4);
    }
}

//! PJRT execution: worker pool + the production `ComputeBackend`.
//!
//! This is the Triton substitution (DESIGN.md): `PjrtPool` spawns
//! `replicas` worker threads, each owning its own `PjRtClient` (the xla
//! crate's client wraps an `Rc` and is not `Send`) and a lazily-compiled
//! cache of executables loaded from `artifacts/*.hlo.txt`. The dynamic
//! batcher upstream feeds whole batches; a bounded job channel provides
//! the backpressure.
//!
//! `PjrtBackend` implements the semantic `ComputeBackend` contract on top:
//! it picks the right compiled batch variant, pads inputs (padding rows
//! are provably inert — see python/tests + backend.rs tests), splits
//! oversized batches, and tiles the pairwise-distance computation into
//! `dist_tile` blocks.

use std::collections::HashMap;
use std::sync::{Arc, Barrier};
use std::thread::JoinHandle;

use crate::runtime::artifact::ArtifactIndex;
use crate::runtime::backend::{ComputeBackend, RtResult, RuntimeError};
use crate::util::chan::{bounded, Sender};
use crate::util::mat::Mat;

/// A tensor crossing the pool boundary: flat f32 data + dims.
#[derive(Debug, Clone)]
pub struct TensorData {
    pub data: Vec<f32>,
    pub dims: Vec<usize>,
}

impl TensorData {
    pub fn from_mat(m: &Mat) -> Self {
        TensorData { data: m.as_slice().to_vec(), dims: vec![m.rows(), m.cols()] }
    }

    pub fn from_vec1(v: &[f32]) -> Self {
        TensorData { data: v.to_vec(), dims: vec![v.len()] }
    }

    pub fn scalar(v: f32) -> Self {
        TensorData { data: vec![v], dims: vec![] }
    }

    pub fn into_mat(self) -> RtResult<Mat> {
        match self.dims.len() {
            2 => Ok(Mat::from_vec(self.data, self.dims[0], self.dims[1])),
            1 => {
                let n = self.dims[0];
                Ok(Mat::from_vec(self.data, 1, n))
            }
            d => Err(RuntimeError::Shape(format!("expected matrix, got rank {d}"))),
        }
    }
}

enum Job {
    /// Execute `artifact` with positional inputs; reply with outputs.
    Exec {
        artifact: String,
        inputs: Vec<TensorData>,
        reply: Sender<Result<Vec<TensorData>, String>>,
    },
    /// Compile the named artifacts now. The barrier forces every worker to
    /// take exactly one Warm job, so all replicas end up warm.
    Warm { artifacts: Vec<String>, barrier: Arc<Barrier>, reply: Sender<Result<(), String>> },
}

/// Replicated PJRT worker pool (the "inference workers" of Figure 1).
pub struct PjrtPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    index: Arc<ArtifactIndex>,
    replicas: usize,
}

impl PjrtPool {
    /// Spawn `replicas` workers with `queue_depth` pending-job slots.
    pub fn new(index: Arc<ArtifactIndex>, replicas: usize, queue_depth: usize) -> Self {
        let replicas = replicas.max(1);
        let (tx, rx) = bounded::<Job>(queue_depth.max(1));
        let workers = (0..replicas)
            .map(|i| {
                let rx = rx.clone();
                let index = index.clone();
                std::thread::Builder::new()
                    .name(format!("pjrt-worker-{i}"))
                    .spawn(move || worker_loop(index, rx))
                    .expect("spawn pjrt worker")
            })
            .collect();
        PjrtPool { tx: Some(tx), workers, index, replicas }
    }

    pub fn index(&self) -> &Arc<ArtifactIndex> {
        &self.index
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Execute an artifact by name. Blocks until a worker replies.
    pub fn call(&self, artifact: &str, inputs: Vec<TensorData>) -> RtResult<Vec<TensorData>> {
        let (rtx, rrx) = bounded(1);
        let job = Job::Exec { artifact: artifact.to_string(), inputs, reply: rtx };
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(job)
            .map_err(|_| RuntimeError::Pool("job queue closed".into()))?;
        match rrx.recv() {
            Some(Ok(outs)) => Ok(outs),
            Some(Err(e)) => Err(RuntimeError::Xla(e)),
            None => Err(RuntimeError::Pool("worker dropped reply".into())),
        }
    }

    /// Compile `artifacts` on every replica (server startup; avoids paying
    /// XLA compile time on the first request).
    pub fn warmup(&self, artifacts: &[String]) -> RtResult<()> {
        let barrier = Arc::new(Barrier::new(self.replicas));
        let mut replies = Vec::new();
        for _ in 0..self.replicas {
            let (rtx, rrx) = bounded(1);
            let job = Job::Warm {
                artifacts: artifacts.to_vec(),
                barrier: barrier.clone(),
                reply: rtx,
            };
            self.tx
                .as_ref()
                .expect("pool shut down")
                .send(job)
                .map_err(|_| RuntimeError::Pool("job queue closed".into()))?;
            replies.push(rrx);
        }
        for r in replies {
            match r.recv() {
                Some(Ok(())) => {}
                Some(Err(e)) => return Err(RuntimeError::Xla(e)),
                None => return Err(RuntimeError::Pool("warmup reply dropped".into())),
            }
        }
        Ok(())
    }

    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if let Some(tx) = self.tx.take() {
            tx.close();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for PjrtPool {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// One worker: own client, own executable cache, serve jobs forever.
fn worker_loop(index: Arc<ArtifactIndex>, rx: crate::util::chan::Receiver<Job>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            crate::log_error!("pjrt", "failed to create PJRT CPU client: {e}");
            // Drain jobs with errors rather than hanging callers.
            while let Some(job) = rx.recv() {
                match job {
                    Job::Exec { reply, .. } => {
                        let _ = reply.send(Err(format!("no pjrt client: {e}")));
                    }
                    Job::Warm { barrier, reply, .. } => {
                        barrier.wait();
                        let _ = reply.send(Err(format!("no pjrt client: {e}")));
                    }
                }
            }
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Some(job) = rx.recv() {
        match job {
            Job::Warm { artifacts, barrier, reply } => {
                // Wait so every replica takes one Warm job before any of
                // them returns to the queue.
                barrier.wait();
                let mut result = Ok(());
                for a in &artifacts {
                    if let Err(e) = ensure_compiled(&client, &index, &mut cache, a) {
                        result = Err(e);
                        break;
                    }
                }
                let _ = reply.send(result);
            }
            Job::Exec { artifact, inputs, reply } => {
                let out = execute_one(&client, &index, &mut cache, &artifact, inputs);
                let _ = reply.send(out);
            }
        }
    }
}

fn ensure_compiled<'a>(
    client: &xla::PjRtClient,
    index: &ArtifactIndex,
    cache: &'a mut HashMap<String, xla::PjRtLoadedExecutable>,
    artifact: &str,
) -> Result<&'a xla::PjRtLoadedExecutable, String> {
    if !cache.contains_key(artifact) {
        let path = index.path_of(artifact).map_err(|e| e.to_string())?;
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| format!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| format!("compile {artifact}: {e}"))?;
        crate::log_debug!(
            "pjrt",
            "compiled {artifact} in {:.1}ms",
            t0.elapsed().as_secs_f64() * 1e3
        );
        cache.insert(artifact.to_string(), exe);
    }
    Ok(cache.get(artifact).unwrap())
}

fn execute_one(
    client: &xla::PjRtClient,
    index: &ArtifactIndex,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    artifact: &str,
    inputs: Vec<TensorData>,
) -> Result<Vec<TensorData>, String> {
    // Shape-check against the manifest before handing to XLA (clearer
    // errors than an opaque runtime failure).
    let spec = index.get(artifact).map_err(|e| e.to_string())?;
    if inputs.len() != spec.inputs.len() {
        return Err(format!(
            "{artifact}: expected {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        ));
    }
    for (t, ispec) in inputs.iter().zip(&spec.inputs) {
        if t.dims != ispec.shape {
            return Err(format!(
                "{artifact}: input '{}' shape {:?} != expected {:?}",
                ispec.name, t.dims, ispec.shape
            ));
        }
        let n: usize = t.dims.iter().product::<usize>().max(1);
        if t.data.len() != n && !(t.dims.is_empty() && t.data.len() == 1) {
            return Err(format!(
                "{artifact}: input '{}' data len {} != shape product {n}",
                ispec.name,
                t.data.len()
            ));
        }
    }

    let exe = ensure_compiled(client, index, cache, artifact)?;

    let literals: Vec<xla::Literal> = inputs
        .iter()
        .map(|t| {
            let lit = xla::Literal::vec1(&t.data);
            if t.dims.is_empty() {
                // rank-0 scalar
                lit.reshape(&[]).map_err(|e| format!("scalar reshape: {e}"))
            } else {
                let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).map_err(|e| format!("reshape {:?}: {e}", t.dims))
            }
        })
        .collect::<Result<Vec<_>, String>>()?;

    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| format!("execute {artifact}: {e}"))?;
    let out_lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| format!("fetch result {artifact}: {e}"))?;
    // aot.py lowers with return_tuple=True: always a tuple, even for one
    // output.
    let parts = out_lit.to_tuple().map_err(|e| format!("untuple {artifact}: {e}"))?;
    parts
        .into_iter()
        .map(|lit| {
            let shape = lit.array_shape().map_err(|e| format!("shape: {e}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = lit.to_vec::<f32>().map_err(|e| format!("to_vec: {e}"))?;
            Ok(TensorData { data, dims })
        })
        .collect()
}

/// Production backend: pads/chunks semantic calls onto compiled variants.
pub struct PjrtBackend {
    pool: Arc<PjrtPool>,
}

impl PjrtBackend {
    pub fn new(pool: Arc<PjrtPool>) -> Self {
        PjrtBackend { pool }
    }

    /// Convenience: load artifacts, spin up a pool, wrap it.
    pub fn from_artifacts_dir(dir: &std::path::Path, replicas: usize) -> RtResult<Self> {
        let index = Arc::new(ArtifactIndex::load(dir)?);
        let pool = Arc::new(PjrtPool::new(index, replicas, 64));
        Ok(PjrtBackend::new(pool))
    }

    pub fn pool(&self) -> &Arc<PjrtPool> {
        &self.pool
    }

    fn index(&self) -> &ArtifactIndex {
        self.pool.index()
    }

    /// Run a batched entry point over arbitrarily many rows: full
    /// `max_batch` chunks, then the smallest variant that fits the tail.
    /// `extra` inputs (head weights) are appended to every chunk call.
    fn run_batched(
        &self,
        entry: &str,
        rows: &Mat,
        extra: &[TensorData],
        n_outputs: usize,
    ) -> RtResult<Vec<Mat>> {
        let idx = self.index();
        let total = rows.rows();
        let max = idx.max_batch();
        let mut outs: Vec<Vec<Mat>> = (0..n_outputs).map(|_| Vec::new()).collect();
        let mut start = 0;
        while start < total {
            let remain = total - start;
            let variant = idx.batch_variant_for(remain.min(max))?;
            let take = remain.min(variant);
            let chunk_idx: Vec<usize> = (start..start + take).collect();
            let chunk = rows.gather_rows(&chunk_idx).pad_rows_to(variant);
            let mut inputs = vec![TensorData::from_mat(&chunk)];
            inputs.extend_from_slice(extra);
            let name = idx.batched_name(entry, variant);
            let result = self.pool.call(&name, inputs)?;
            if result.len() != n_outputs {
                return Err(RuntimeError::Shape(format!(
                    "{name}: expected {n_outputs} outputs, got {}",
                    result.len()
                )));
            }
            for (slot, t) in outs.iter_mut().zip(result) {
                slot.push(t.into_mat()?.take_rows(take));
            }
            start += take;
        }
        Ok(outs
            .into_iter()
            .map(|parts| {
                let mut it = parts.into_iter();
                let first = it.next().expect("at least one chunk");
                it.fold(first, |acc, m| acc.vstack(&m))
            })
            .collect())
    }
}

impl ComputeBackend for PjrtBackend {
    fn embed(&self, images: &Mat) -> RtResult<Mat> {
        let mut out = self.run_batched("embed", images, &[], 1)?;
        Ok(out.remove(0))
    }

    fn forward(&self, images: &Mat, w: &Mat, b: &[f32]) -> RtResult<(Mat, Mat)> {
        let extra = [TensorData::from_mat(w), TensorData::from_vec1(b)];
        let mut out = self.run_batched("forward", images, &extra, 2)?;
        let emb = out.remove(0);
        let scores = out.remove(0);
        Ok((emb, scores))
    }

    fn scores(&self, logits: &Mat) -> RtResult<Mat> {
        let mut out = self.run_batched("scores", logits, &[], 1)?;
        Ok(out.remove(0))
    }

    fn sqdist(&self, x: &Mat, y: &Mat) -> RtResult<Mat> {
        if x.cols() != y.cols() {
            return Err(RuntimeError::Shape(format!(
                "sqdist dims differ: {} vs {}",
                x.cols(),
                y.cols()
            )));
        }
        let t = self.index().model.dist_tile;
        let name = format!("sqdist_t{t}");
        let (m, n) = (x.rows(), y.rows());
        let mut out = Mat::zeros(m, n);
        let mut i = 0;
        while i < m {
            let ti = (m - i).min(t);
            let xi: Vec<usize> = (i..i + ti).collect();
            let xt = x.gather_rows(&xi).pad_rows_to(t);
            let mut j = 0;
            while j < n {
                let tj = (n - j).min(t);
                let yj: Vec<usize> = (j..j + tj).collect();
                let yt = y.gather_rows(&yj).pad_rows_to(t);
                let res = self
                    .pool
                    .call(&name, vec![TensorData::from_mat(&xt), TensorData::from_mat(&yt)])?;
                let block = res.into_iter().next().expect("one output").into_mat()?;
                for bi in 0..ti {
                    let src = block.row(bi);
                    let dst = out.row_mut(i + bi);
                    dst[j..j + tj].copy_from_slice(&src[..tj]);
                }
                j += tj;
            }
            i += ti;
        }
        Ok(out)
    }

    fn train_step(
        &self,
        w: &mut Mat,
        b: &mut [f32],
        x: &Mat,
        y_onehot: &Mat,
        lr: f32,
    ) -> RtResult<f32> {
        let bt = self.index().model.train_batch;
        if x.rows() > bt {
            return Err(RuntimeError::Shape(format!(
                "train_step minibatch {} > compiled batch {bt}",
                x.rows()
            )));
        }
        let xp = x.pad_rows_to(bt);
        let yp = y_onehot.pad_rows_to(bt);
        let inputs = vec![
            TensorData::from_mat(w),
            TensorData::from_vec1(b),
            TensorData::from_mat(&xp),
            TensorData::from_mat(&yp),
            TensorData::scalar(lr),
        ];
        let mut res = self.pool.call("train_step", inputs)?;
        if res.len() != 3 {
            return Err(RuntimeError::Shape(format!(
                "train_step: expected 3 outputs, got {}",
                res.len()
            )));
        }
        let loss_t = res.pop().unwrap();
        let b_t = res.pop().unwrap();
        let w_t = res.pop().unwrap();
        *w = w_t.into_mat()?;
        b.copy_from_slice(&b_t.data);
        Ok(loss_t.data[0])
    }

    fn eval_logits(&self, x: &Mat, w: &Mat, b: &[f32]) -> RtResult<Mat> {
        let be = self.index().model.eval_batch;
        let name = format!("eval_logits_b{be}");
        let mut rows_out: Vec<Mat> = Vec::new();
        let mut start = 0;
        while start < x.rows() {
            let take = (x.rows() - start).min(be);
            let idxs: Vec<usize> = (start..start + take).collect();
            let chunk = x.gather_rows(&idxs).pad_rows_to(be);
            let inputs = vec![
                TensorData::from_mat(&chunk),
                TensorData::from_mat(w),
                TensorData::from_vec1(b),
            ];
            let res = self.pool.call(&name, inputs)?;
            let m = res.into_iter().next().expect("one output").into_mat()?;
            rows_out.push(m.take_rows(take));
            start += take;
        }
        let mut it = rows_out.into_iter();
        let first = it.next().ok_or_else(|| RuntimeError::Shape("empty eval".into()))?;
        Ok(it.fold(first, |acc, m| acc.vstack(&m)))
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn warmup_serving(&self, batch_size: usize) -> RtResult<()> {
        let idx = self.index();
        let variant = idx.batch_variant_for(batch_size.min(idx.max_batch()))?;
        let mut names = vec![
            idx.batched_name("forward", variant),
            idx.batched_name("forward", idx.max_batch()),
            idx.batched_name("embed", idx.max_batch()),
            "embed_b1".to_string(), // the pipeline's width probe
        ];
        names.dedup();
        self.pool.warmup(&names)
    }
}

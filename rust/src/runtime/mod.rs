//! Runtime layer: executes the AOT artifacts from the L3 hot path.
//!
//! * [`artifact`] — parses `artifacts/manifest.json` (written by
//!   `python/compile/aot.py`) and resolves batch-size variants.
//! * [`backend`] — the `ComputeBackend` contract the rest of the system
//!   programs against, plus `HostBackend`, a pure-Rust reference
//!   implementation (used by unit tests and as the numerics cross-check
//!   for the PJRT path).
//! * [`pjrt`] — the real thing: per-worker `PjRtClient` (the client is
//!   `Rc`-based, hence not `Send` — every worker thread owns its own
//!   client and compiled executables), a job-channel `PjrtPool` standing
//!   in for the paper's Triton replicas, and `PjrtBackend` which handles
//!   batch padding/variant selection.
//!
//! Python never runs here: everything executes through the `xla` crate's
//! PJRT CPU client from HLO text (see /opt/xla-example/README.md for why
//! text, not serialized protos).

pub mod artifact;
pub mod backend;
pub mod pjrt;

pub use artifact::{ArtifactIndex, ArtifactSpec};
pub use backend::{ComputeBackend, HostBackend, RuntimeError};
pub use pjrt::{PjrtBackend, PjrtPool};

/// Default location of `make artifacts` output, relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory: explicit arg, `ALAAS_ARTIFACTS` env,
/// or walking up from cwd looking for `artifacts/manifest.json` (tests and
/// examples run from different depths).
pub fn find_artifacts_dir(explicit: Option<&str>) -> Option<std::path::PathBuf> {
    if let Some(dir) = explicit {
        let p = std::path::PathBuf::from(dir);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    if let Ok(env) = std::env::var("ALAAS_ARTIFACTS") {
        let p = std::path::PathBuf::from(env);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let candidate = cur.join(DEFAULT_ARTIFACTS_DIR);
        if candidate.join("manifest.json").exists() {
            return Some(candidate);
        }
        if !cur.pop() {
            return None;
        }
    }
}

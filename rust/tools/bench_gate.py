#!/usr/bin/env python3
"""Bench-regression gate for the CI lane.

Reads the BENCH_*.json files the bench targets emit (rpc_wire ->
BENCH_PR2.json, conn_pool -> BENCH_PR4.json, mux_scatter ->
BENCH_PR8.json, tenancy_soak -> BENCH_PR9.json), matches each against the
committed baseline (tools/bench_baseline.json), and fails the job when a
gated metric regresses more than the configured tolerance below its
baseline value.

Baseline values are deliberately machine-independent *ratios* (payload
cut, pooled-vs-per-call speedup): CI runners vary wildly in absolute
speed, but a ratio of two measurements taken on the same runner in the
same process is stable. Entries with a `null` baseline are record-only:
the gate prints the measured value so maintainers can ratchet the
baseline from a green run's artifact, but never fails on them.

Usage (CI runs this from the rust/ package root):

    python3 tools/bench_gate.py --baseline tools/bench_baseline.json \
        ../BENCH_PR2.json ../BENCH_PR4.json ../BENCH_PR8.json ../BENCH_PR9.json
"""

import argparse
import json
import sys


def lookup(doc, dotted):
    cur = doc
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("results", nargs="+", help="BENCH_*.json files to gate")
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    tolerance = float(baseline.get("tolerance", 0.15))

    docs = {}
    for path in args.results:
        try:
            with open(path) as f:
                doc = json.load(f)
        except FileNotFoundError:
            print(f"FAIL  missing bench output: {path}")
            return 1
        name = doc.get("bench")
        if not name:
            print(f"FAIL  {path} has no 'bench' field")
            return 1
        docs[name] = (path, doc)

    failures = 0
    checked = 0
    for check in baseline.get("checks", []):
        bench, metric = check["bench"], check["metric"]
        floor = check.get("baseline")
        if bench not in docs:
            print(f"FAIL  no results for bench '{bench}' (needed by {metric})")
            failures += 1
            continue
        path, doc = docs[bench]
        measured = lookup(doc, metric)
        if not isinstance(measured, (int, float)):
            print(f"FAIL  {bench}:{metric} missing from {path}")
            failures += 1
            continue
        if floor is None:
            print(f"note  {bench}:{metric} = {measured:.4g} (record-only, no baseline)")
            continue
        checked += 1
        cutoff = float(floor) * (1.0 - tolerance)
        if measured < cutoff:
            print(
                f"FAIL  {bench}:{metric} = {measured:.4g} "
                f"< {cutoff:.4g} (baseline {floor} - {tolerance:.0%})"
            )
            failures += 1
        else:
            print(f"ok    {bench}:{metric} = {measured:.4g} (>= {cutoff:.4g})")

    if failures:
        print(f"\nbench gate: {failures} regression(s) past the {tolerance:.0%} tolerance")
        return 1
    print(f"\nbench gate: {checked} gated metric(s) within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())

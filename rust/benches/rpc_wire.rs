//! JSON (v1) vs binary-tensor (v2) wire comparison (DESIGN.md §Wire):
//! encode, decode, and full round trip over loopback TCP for a 10k x 64
//! embedding matrix — the `select_shard with_embeddings` shape the
//! cluster's refine protocol ships per worker.
//!
//! Run: `cargo bench --bench rpc_wire`
//!
//! Besides the table, the bench writes a machine-readable
//! `BENCH_PR2.json` at the repo root so the perf trajectory is tracked
//! across PRs.

use std::net::TcpListener;
use std::time::Duration;

use alaas::json::{self, Map, Value};
use alaas::server::rpc;
use alaas::server::wire::{self, Payload, WireMode};
use alaas::util::bench::{fmt_dur, measure, Sample, Table};
use alaas::util::mat::Mat;
use alaas::util::rng::Rng;

const ROWS: usize = 10_000;
const COLS: usize = 64;

/// The envelope a worker's refine reply would carry: slim candidate list
/// in the header, the [ROWS, COLS] embedding matrix as the bulk payload.
fn payload(m: Mat) -> (Value, Vec<Mat>) {
    let mut p = Payload::default();
    let ph = p.stash_mat(m);
    let mut result = Map::new();
    result.insert("scan_ms", Value::Number(12.5));
    result.insert("cand_emb", ph);
    let mut env = Map::new();
    env.insert("id", Value::from(1u64));
    env.insert("result", Value::Object(result));
    (Value::Object(env), p.tensors)
}

struct ModeStats {
    mode: WireMode,
    bytes: usize,
    encode: Sample,
    decode: Sample,
    roundtrip: Sample,
}

fn stat_obj(s: &ModeStats) -> Value {
    let ms = |d: Duration| Value::Number(d.as_secs_f64() * 1e3);
    let mut m = Map::new();
    m.insert("payload_bytes", Value::from(s.bytes));
    m.insert("encode_ms_mean", ms(s.encode.mean()));
    m.insert("decode_ms_mean", ms(s.decode.mean()));
    m.insert("roundtrip_ms_mean", ms(s.roundtrip.mean()));
    m.insert("roundtrip_ms_p50", ms(s.roundtrip.percentile(0.5)));
    m.insert("roundtrip_ms_min", ms(s.roundtrip.min()));
    Value::Object(m)
}

fn main() {
    let mut rng = Rng::new(7);
    let m = Mat::from_vec(
        (0..ROWS * COLS).map(|_| rng.normal_f32()).collect(),
        ROWS,
        COLS,
    );
    let (env, tensors) = payload(m);

    // Loopback echo peer: decode each frame and send it back re-encoded
    // in the same mode, i.e. one full server-side codec pass per trip.
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        while let Ok((mut s, _)) = listener.accept() {
            s.set_nodelay(true).ok();
            loop {
                let buf = match rpc::read_frame(&mut s) {
                    Ok(b) => b,
                    Err(_) => break,
                };
                let (v, t, mode) = wire::decode_payload(&buf).expect("echo decode");
                let bytes = wire::encode_payload(&v, &t, mode).expect("echo encode");
                if rpc::write_frame(&mut s, &bytes).is_err() {
                    break;
                }
            }
        }
    });

    let mut table = Table::new(
        &format!("rpc_wire: {ROWS}x{COLS} f32 matrix, JSON vs binary frames"),
        &["wire", "payload", "encode", "decode", "roundtrip(mean)", "roundtrip(min)"],
    );
    let mut stats = Vec::new();
    for mode in [WireMode::Json, WireMode::Binary] {
        let bytes = wire::encode_payload(&env, &tensors, mode).expect("encode");
        let nbytes = bytes.len();
        let encode = measure(1, 5, || {
            let b = wire::encode_payload(&env, &tensors, mode).unwrap();
            assert_eq!(b.len(), nbytes);
        });
        let decode = measure(1, 5, || {
            let (_, t, m) = wire::decode_payload(&bytes).unwrap();
            assert_eq!(m, mode);
            // json inlines, so sections only exist on the binary wire
            assert_eq!(t.len(), usize::from(mode == WireMode::Binary));
        });
        let mut conn = std::net::TcpStream::connect(addr).expect("connect echo");
        conn.set_nodelay(true).ok();
        let roundtrip = measure(1, 5, || {
            let b = wire::encode_payload(&env, &tensors, mode).unwrap();
            rpc::write_frame(&mut conn, &b).unwrap();
            let back = rpc::read_frame(&mut conn).unwrap();
            let (_, _, m) = wire::decode_payload(&back).unwrap();
            assert_eq!(m, mode);
        });
        table.row(&[
            mode.as_str().to_string(),
            format!("{:.2} MiB", nbytes as f64 / (1024.0 * 1024.0)),
            fmt_dur(encode.mean()),
            fmt_dur(decode.mean()),
            fmt_dur(roundtrip.mean()),
            fmt_dur(roundtrip.min()),
        ]);
        stats.push(ModeStats { mode, bytes: nbytes, encode, decode, roundtrip });
    }
    table.print();

    let (j, b) = (&stats[0], &stats[1]);
    let payload_ratio = j.bytes as f64 / b.bytes as f64;
    let rt_speedup =
        j.roundtrip.mean().as_secs_f64() / b.roundtrip.mean().as_secs_f64().max(1e-12);
    println!(
        "\npayload ratio (json/binary): {payload_ratio:.2}x   \
         roundtrip speedup: {rt_speedup:.2}x"
    );

    let mut root = Map::new();
    root.insert("bench", Value::from("rpc_wire"));
    root.insert("case", Value::from(format!("{ROWS}x{COLS}")));
    root.insert(j.mode.as_str(), stat_obj(j));
    root.insert(b.mode.as_str(), stat_obj(b));
    root.insert("payload_ratio", Value::Number(payload_ratio));
    root.insert("roundtrip_speedup", Value::Number(rt_speedup));
    let out = json::to_string_pretty(&Value::Object(root));
    // cargo runs benches from the package root (rust/); the tracking file
    // lives at the repo root next to ROADMAP.md
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_PR2.json"
    } else {
        "BENCH_PR2.json"
    };
    match std::fs::write(path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

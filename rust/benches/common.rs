#![allow(dead_code)]
//! Shared setup for the bench targets (criterion is offline-unavailable;
//! these are `harness = false` binaries over `alaas::util::bench`).

use std::sync::Arc;

use alaas::config::StoreConfig;
use alaas::data::{generate_into_store, DatasetSpec};
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::{ArtifactIndex, HostBackend, PjrtBackend, PjrtPool};
use alaas::store::{Manifest, ObjectStore, StoreRouter};

/// PJRT backend when artifacts exist, host fallback otherwise (benches
/// print which one so EXPERIMENTS.md records it).
pub fn backend(replicas: usize) -> Arc<dyn ComputeBackend> {
    match alaas::runtime::find_artifacts_dir(None) {
        Some(dir) => {
            let index = Arc::new(ArtifactIndex::load(&dir).expect("manifest parses"));
            let pool = Arc::new(PjrtPool::new(index, replicas, 64));
            let be = PjrtBackend::new(pool);
            // compile the serving variants up front so the first measured
            // run is not paying XLA compile time
            be.pool()
                .warmup(&[
                    "forward_b16".into(),
                    "forward_b64".into(),
                    "forward_b128".into(),
                    "forward_b1".into(),
                ])
                .ok();
            eprintln!("[bench] backend: pjrt ({} replicas)", replicas);
            Arc::new(be)
        }
        None => {
            eprintln!("[bench] backend: HOST FALLBACK (run `make artifacts` for pjrt)");
            Arc::new(HostBackend::new())
        }
    }
}

/// Provision a dataset into a router's s3sim backing store (writes bypass
/// the latency model, like a pre-filled bucket).
pub fn provision(store: &StoreRouter, spec: &DatasetSpec, bucket: &str) -> Manifest {
    let scratch: Arc<dyn ObjectStore> = Arc::new(alaas::store::MemStore::new());
    let manifest = generate_into_store(spec, &scratch, "s3sim", bucket);
    for key in scratch.list("").unwrap() {
        store.s3sim_backing().put(&key, &scratch.get(&key).unwrap()).unwrap();
    }
    manifest
}

/// The S3-like store model used by the paper-protocol benches.
pub fn s3_store() -> StoreRouter {
    StoreRouter::new(
        "/tmp",
        &StoreConfig { get_latency_us: 300, bandwidth_mib_s: 200.0, jitter: 0.05 },
    )
}

#[allow(dead_code)]
fn main() {}

//! Table 2: one-round AL latency + throughput, ALaaS vs the baseline tool
//! dataflows (DeepAL / ModAL / ALiPy / libact profiles — DESIGN.md
//! §Substitutions).
//!
//! Paper protocol (scaled 1/10): initial model on the seed split, then a
//! one-round least-confidence scan of the pool selecting `budget`, on the
//! simulated S3 store. Latency is the full scan+select, throughput is
//! pool/latency. Accuracy (top-1/top-5) is the post-update model on the
//! test split — identical across tools running the same strategy, as in
//! the paper's ALaaS/DeepAL/ModAL/ALiPy rows.
//!
//! Run: `cargo bench --bench table2_tools`

#[path = "common.rs"]
mod common;

use std::sync::Arc;
use std::time::Instant;

use alaas::baselines::{alaas_profile, table2_baselines};
use alaas::cache::DataCache;
use alaas::data::{generate, DatasetSpec};
use alaas::pipeline::run_pipeline;
use alaas::sim::AlExperiment;
use alaas::strategies::{self, SelectCtx};
use alaas::trainer::{LinearHead, TrainConfig};
use alaas::util::bench::Table;
use alaas::util::mat::Mat;

const INIT: usize = 1000;
const POOL: usize = 4000;
const TEST: usize = 1000;
const BUDGET: usize = 1000;
const RUNS: usize = 3;

fn main() {
    let spec = DatasetSpec::cifarsim(2022).with_sizes(INIT, POOL, TEST);
    let backend = common::backend(2);
    let store = common::s3_store();
    let manifest = common::provision(&store, &spec, "t2");

    // accuracy of the updated model (shared across tools; LC strategy)
    eprintln!("[table2] measuring post-update accuracy (one-round LC)...");
    let gen = generate(&spec);
    let mut exp = AlExperiment::from_generated(
        backend.clone(),
        &gen,
        spec.num_classes,
        TrainConfig::default(),
        7,
    )
    .expect("experiment");
    let acc = exp.one_round("least_confidence", BUDGET).expect("one round");

    let head = LinearHead::zeros(64, 10);
    let lc = strategies::by_name("least_confidence").unwrap();
    let mut table = Table::new(
        "Table 2 — one-round AL on cifarsim (pool 40k->4k scaled), LC, s3sim store",
        &[
            "AL Tool",
            "Top-1 (%)",
            "Top-5 (%)",
            "One-round latency (s)",
            "Throughput (img/s)",
            "vs ALaaS",
        ],
    );

    let mut profiles = table2_baselines();
    profiles.push(alaas_profile(16));
    let mut rows: Vec<(String, f64, f64)> = Vec::new(); // (name, mean, std)

    for profile in &profiles {
        let params = profile.params(2);
        let mut times = Vec::new();
        for run in 0..RUNS {
            // fresh cache per run unless the tool has one (only ALaaS);
            // ALaaS's first run is the cold one, later runs exercise the
            // cache exactly as repeated AL rounds would.
            let cache = if profile.cache {
                DataCache::new(512 << 20, 16, run > 0)
            } else {
                DataCache::new(0, 1, false)
            };
            let t0 = Instant::now();
            let out = run_pipeline(
                &manifest.pool,
                &store,
                &cache,
                &backend,
                &head,
                &params,
                None,
            )
            .expect("scan");
            // selection phase on the scan outputs
            let labeled = Mat::zeros(0, out.embeddings.cols());
            let ctx = SelectCtx {
                scores: &out.scores,
                embeddings: &out.embeddings,
                labeled: &labeled,
                backend: backend.as_ref(),
                seed: 1,
            };
            let sel = lc.select(&ctx, BUDGET).expect("select");
            assert_eq!(sel.len(), BUDGET);
            times.push(t0.elapsed().as_secs_f64());
            eprintln!(
                "[table2] {:12} run {run}: {:.2}s",
                profile.name,
                times.last().unwrap()
            );
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
            / times.len() as f64;
        rows.push((profile.name.to_string(), mean, var.sqrt()));
    }

    let alaas_mean = rows.last().unwrap().1;
    for (name, mean, std) in &rows {
        table.row(&[
            name.clone(),
            format!("{:.2}", acc.top1 * 100.0),
            format!("{:.2}", acc.top5 * 100.0),
            format!("{mean:.2} ± {std:.2}"),
            format!("{:.1}", POOL as f64 / mean),
            format!("{:.2}x", mean / alaas_mean),
        ]);
    }
    table.print();
    println!(
        "\npaper shape check: ALaaS lowest latency / highest throughput; \
         serial tools {:.1}-{:.1}x slower (paper: 3.2-4.4x at 40k scale).",
        rows[..rows.len() - 1].iter().map(|r| r.1 / alaas_mean).fold(f64::MAX, f64::min),
        rows[..rows.len() - 1].iter().map(|r| r.1 / alaas_mean).fold(0.0, f64::max),
    );
    let _ = Arc::strong_count(&backend);
}

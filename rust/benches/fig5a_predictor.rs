//! Fig 5a: the negative-exponential performance predictor vs the actual
//! AL accuracy curve (least-confidence, 8 rounds, cifarsim).
//!
//! For each round k >= 3 the predictor is fit on rounds 0..k and asked for
//! round k's accuracy; the paper's claim is that prediction tracks the
//! actual curve closely ("can foresee the accuracy very accurately").
//!
//! Run: `cargo bench --bench fig5a_predictor`

#[path = "common.rs"]
mod common;

use alaas::agent::NegExpPredictor;
use alaas::data::{generate, DatasetSpec};
use alaas::sim::AlExperiment;
use alaas::trainer::TrainConfig;
use alaas::util::bench::Table;

const ROUNDS: usize = 8;
const ROUND_BUDGET: usize = 300;

fn main() {
    let backend = common::backend(2);
    let spec = DatasetSpec::cifarsim(5).with_sizes(600, 3000, 800);
    let gen = generate(&spec);
    let mut exp = AlExperiment::from_generated(
        backend,
        &gen,
        spec.num_classes,
        TrainConfig::default(),
        5,
    )
    .expect("experiment");

    // run the real 8-round LC curve
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for r in 0..ROUNDS {
        let acc = exp
            .round("least_confidence", ROUND_BUDGET)
            .expect("round")
            .expect("pool large enough");
        xs.push(((r + 1) * ROUND_BUDGET) as f64);
        ys.push(acc.top1);
        eprintln!("[fig5a] round {r}: acc {:.4}", acc.top1);
    }

    let mut table = Table::new(
        "Fig 5a — predictor vs actual accuracy (LC, 8 rounds x 300 labels, cifarsim)",
        &["Round", "Labels", "Actual top-1", "Predicted", "Abs error (pts)"],
    );
    let mut errs = Vec::new();
    for k in 0..ROUNDS {
        let (pred_str, err_str) = if k >= 3 {
            // fit on the history before round k, predict round k
            let p = NegExpPredictor::fit(&xs[..k], &ys[..k]).expect("fit");
            let pred = p.predict(xs[k]);
            errs.push((pred - ys[k]).abs());
            (format!("{:.4}", pred), format!("{:.2}", 100.0 * (pred - ys[k]).abs()))
        } else {
            ("-".to_string(), "-".to_string())
        };
        table.row(&[
            format!("{k}"),
            format!("{}", (k + 1) * ROUND_BUDGET),
            format!("{:.4}", ys[k]),
            pred_str,
            err_str,
        ]);
    }
    table.print();
    let mean_err = 100.0 * errs.iter().sum::<f64>() / errs.len() as f64;
    println!(
        "\nmean |error| over predicted rounds: {mean_err:.2} pts \
         (paper shape: prediction hugs the actual curve after a few rounds)."
    );
}

//! Fig 4b: end-to-end throughput per AL strategy (one-round protocol):
//! shared pipelined scan + per-strategy selection phase.
//!
//! Paper shape: LC highest (top-k over precomputed scores), Core-Set
//! lowest ("heavy design"), diversity methods in between.
//!
//! Run: `cargo bench --bench fig4b_strategy_throughput`

#[path = "common.rs"]
mod common;

use std::time::Instant;

use alaas::cache::DataCache;
use alaas::data::DatasetSpec;
use alaas::pipeline::{run_pipeline, PipelineParams};
use alaas::strategies::SelectCtx;
use alaas::trainer::LinearHead;
use alaas::util::bench::{fmt_dur, Table};
use alaas::util::mat::Mat;

const POOL: usize = 4000;
const BUDGET: usize = 1000;

fn main() {
    let backend = common::backend(2);
    let store = common::s3_store();
    let spec = DatasetSpec::cifarsim(2022).with_sizes(0, POOL, 0);
    let manifest = common::provision(&store, &spec, "f4b");

    // shared scan (every strategy consumes the same embeddings/scores)
    let head = LinearHead::zeros(64, 10);
    let cache = DataCache::new(512 << 20, 16, true);
    let t0 = Instant::now();
    let out = run_pipeline(
        &manifest.pool,
        &store,
        &cache,
        &backend,
        &head,
        &PipelineParams::default(),
        None,
    )
    .expect("scan");
    let scan = t0.elapsed();
    eprintln!("[fig4b] shared scan of {POOL}: {}", fmt_dur(scan));

    let labeled = Mat::zeros(0, out.embeddings.cols());
    let mut table = Table::new(
        "Fig 4b — one-round AL throughput per strategy (scan + select), cifarsim 4k pool",
        &["Strategy", "Select time", "End-to-end (img/s)", "Select-only (img/s)"],
    );
    for s in alaas::strategies::zoo() {
        let ctx = SelectCtx {
            scores: &out.scores,
            embeddings: &out.embeddings,
            labeled: &labeled,
            backend: backend.as_ref(),
            seed: 1,
        };
        // median of 3 runs
        let mut times = Vec::new();
        for _ in 0..3 {
            let t0 = Instant::now();
            let sel = s.select(&ctx, BUDGET).expect("select");
            assert_eq!(sel.len(), BUDGET);
            times.push(t0.elapsed());
        }
        times.sort();
        let select = times[1];
        let total = scan + select;
        table.row(&[
            s.name().to_string(),
            fmt_dur(select),
            format!("{:.1}", POOL as f64 / total.as_secs_f64()),
            format!("{:.0}", POOL as f64 / select.as_secs_f64().max(1e-9)),
        ]);
        eprintln!("[fig4b] {:18} select {}", s.name(), fmt_dur(select));
    }
    table.print();
    println!(
        "\npaper shape check: least_confidence fastest, core_set slowest \
         (its refinement passes are the 'heavy design')."
    );
}

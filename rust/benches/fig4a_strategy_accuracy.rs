//! Fig 4a: one-round AL accuracy per strategy on cifarsim, with the
//! Random lower bound and the entire-dataset upper bound.
//!
//! Paper shape: Core-Set best, DBAL/MC next, everything informed above
//! Random, everything below the full-data bound.
//!
//! Run: `cargo bench --bench fig4a_strategy_accuracy`

#[path = "common.rs"]
mod common;

use alaas::data::{generate, DatasetSpec};
use alaas::sim::AlExperiment;
use alaas::trainer::TrainConfig;
use alaas::util::bench::Table;

const INIT: usize = 1000;
const POOL: usize = 4000;
const TEST: usize = 1000;
const BUDGET: usize = 1000;
// Accuracy is seed-noisy at this scale; average a few seeds.
const SEEDS: [u64; 3] = [2022, 2023, 2024];

fn main() {
    let backend = common::backend(2);
    let mut table = Table::new(
        "Fig 4a — one-round AL accuracy, ResNet-18-sim / cifarsim (mean of 3 seeds)",
        &["Strategy", "Top-1 (%)", "Top-5 (%)", "Δ vs Random (pts)"],
    );

    let strategies = alaas::strategies::zoo_names();
    let mut top1 = vec![0.0f64; strategies.len()];
    let mut top5 = vec![0.0f64; strategies.len()];
    let mut upper1 = 0.0f64;
    let mut upper5 = 0.0f64;

    for &seed in &SEEDS {
        let spec = DatasetSpec::cifarsim(seed).with_sizes(INIT, POOL, TEST);
        let gen = generate(&spec);
        let mut exp = AlExperiment::from_generated(
            backend.clone(),
            &gen,
            spec.num_classes,
            TrainConfig::default(),
            seed,
        )
        .expect("experiment");
        for (i, s) in strategies.iter().enumerate() {
            let acc = exp.one_round(s, BUDGET).expect("one round");
            eprintln!("[fig4a] seed {seed} {s:18} top1 {:.4}", acc.top1);
            top1[i] += acc.top1;
            top5[i] += acc.top5;
        }
        let ub = exp.upper_bound().expect("upper bound");
        upper1 += ub.top1;
        upper5 += ub.top5;
    }
    let n = SEEDS.len() as f64;
    let rnd_idx = strategies.iter().position(|s| *s == "random").unwrap();
    let rnd1 = top1[rnd_idx] / n;

    // print in descending top-1 order, paper-style
    let mut order: Vec<usize> = (0..strategies.len()).collect();
    order.sort_by(|&a, &b| top1[b].partial_cmp(&top1[a]).unwrap());
    for i in order {
        table.row(&[
            strategies[i].to_string(),
            format!("{:.2}", 100.0 * top1[i] / n),
            format!("{:.2}", 100.0 * top5[i] / n),
            format!("{:+.2}", 100.0 * (top1[i] / n - rnd1)),
        ]);
    }
    table.row(&[
        "(entire dataset)".into(),
        format!("{:.2}", 100.0 * upper1 / n),
        format!("{:.2}", 100.0 * upper5 / n),
        format!("{:+.2}", 100.0 * (upper1 / n - rnd1)),
    ]);
    table.print();
    println!(
        "\npaper shape check: informed strategies >= Random; upper bound on top; \
         Core-Set / DBAL / MC near the front."
    );
}

//! Multiplexed vs classic pooled scatter (DESIGN.md §Wire): the same
//! `FAN`-wide fan-out of select-shaped RPCs driven (a) as in-flight
//! requests interleaved on one muxed connection (`pool.start`/`pool.wait`,
//! no thread per call) and (b) as blocking calls on a classic pool with
//! one parked connection per concurrent call (one thread per call — the
//! pre-mux scatter shape).
//!
//! Run: `cargo bench --bench mux_scatter`
//!
//! Besides the table, the bench writes a machine-readable `BENCH_PR8.json`
//! at the repo root; CI's bench-regression gate (`tools/bench_gate.py`)
//! checks its ratios against `tools/bench_baseline.json`. The hard gate is
//! `single_conn`: the whole muxed scatter must ride exactly one socket.

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use alaas::json::{self, Map, Value};
use alaas::metrics::Registry;
use alaas::server::pool::{ConnPool, PoolConfig};
use alaas::server::rpc;
use alaas::server::wire::{self, Payload, WireMode};
use alaas::util::bench::{fmt_dur, measure, Sample, Table};
use alaas::util::mat::Mat;
use alaas::util::rng::Rng;

/// Concurrent requests per scatter round — a plausible shard fan-out.
const FAN: usize = 8;
const ROWS: usize = 2_000;
const COLS: usize = 32;
const RPC_TIMEOUT: Duration = Duration::from_secs(10);

/// Loopback RPC server speaking the real dispatch loop (`serve_conn`),
/// counting accepted sockets so the bench can pin connection usage.
fn start_server(mux: bool) -> (String, Arc<AtomicUsize>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap().to_string();
    let accepted = Arc::new(AtomicUsize::new(0));
    let counter = accepted.clone();
    std::thread::spawn(move || {
        let shutdown = Arc::new(AtomicBool::new(false));
        let metrics = Registry::new();
        for conn in listener.incoming() {
            let Ok(mut stream) = conn else { continue };
            counter.fetch_add(1, Ordering::SeqCst);
            let metrics = metrics.clone();
            let shutdown = shutdown.clone();
            std::thread::spawn(move || {
                rpc::serve_conn(
                    &mut stream,
                    "bench",
                    &shutdown,
                    &metrics,
                    None,
                    WireMode::Binary,
                    move |method, params, _mode| match method {
                        "hello" => Ok(Payload::json(wire::hello_reply(
                            &params.value,
                            WireMode::Binary,
                            mux,
                        ))),
                        "select" => Ok(params.to_payload()),
                        other => Err(format!("unknown method '{other}'")),
                    },
                );
            });
        }
    });
    (addr, accepted)
}

fn select_payload() -> Payload {
    let mut rng = Rng::new(7);
    let m = Mat::from_vec(
        (0..ROWS * COLS).map(|_| rng.normal_f32()).collect(),
        ROWS,
        COLS,
    );
    let mut params = Payload::default();
    let ph = params.stash_mat(m);
    let mut p = Map::new();
    p.insert("session", Value::from("bench"));
    p.insert("budget", Value::from(16usize));
    p.insert("cand_emb", ph);
    params.value = Value::Object(p);
    params
}

fn main() {
    let params = select_payload();

    // muxed scatter: FAN requests started back-to-back on one shared
    // connection, then drained — the coordinator's phase-1/phase-3 shape
    let (mux_addr, mux_accepted) = start_server(true);
    let mux_pool = ConnPool::new(
        PoolConfig { max_idle_per_peer: FAN, idle_timeout_ms: 60_000 },
        WireMode::Binary,
        Some(Registry::new()),
    );
    let mux_sample: Sample = measure(5, 40, || {
        let calls: Vec<_> = (0..FAN)
            .map(|_| {
                mux_pool
                    .start(&mux_addr, "select", &params, Some(RPC_TIMEOUT))
                    .expect("start")
                    .expect("peer granted mux")
            })
            .collect();
        for c in calls {
            let body = mux_pool.wait(c).expect("mux reply");
            assert!(!body.value.is_null());
        }
    });
    let mux_sockets = mux_accepted.load(Ordering::SeqCst);

    // classic scatter: the pre-mux shape — one blocking call per thread,
    // one parked connection per concurrent call
    let (cls_addr, cls_accepted) = start_server(false);
    let cls_pool = ConnPool::new(
        PoolConfig { max_idle_per_peer: FAN, idle_timeout_ms: 60_000 },
        WireMode::Binary,
        Some(Registry::new()),
    )
    .with_mux(false);
    let cls_sample: Sample = measure(5, 40, || {
        std::thread::scope(|sc| {
            let handles: Vec<_> = (0..FAN)
                .map(|_| {
                    sc.spawn(|| {
                        let body = cls_pool
                            .call(&cls_addr, "select", &params, Some(RPC_TIMEOUT))
                            .expect("classic reply");
                        assert!(!body.value.is_null());
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("scatter thread");
            }
        });
    });
    let cls_sockets = cls_accepted.load(Ordering::SeqCst);

    let speedup = cls_sample.mean().as_secs_f64() / mux_sample.mean().as_secs_f64().max(1e-12);
    let mut table = Table::new(
        &format!("mux_scatter: {FAN}-wide scatter of {ROWS}x{COLS} selects, mux vs classic pool"),
        &["path", "round(mean)", "round(p50)", "sockets"],
    );
    table.row(&[
        "mux".into(),
        fmt_dur(mux_sample.mean()),
        fmt_dur(mux_sample.percentile(0.5)),
        mux_sockets.to_string(),
    ]);
    table.row(&[
        "classic".into(),
        fmt_dur(cls_sample.mean()),
        fmt_dur(cls_sample.percentile(0.5)),
        cls_sockets.to_string(),
    ]);
    table.print();
    println!("mux_vs_pooled speedup: {speedup:.2}x");

    let ms = |d: Duration| Value::Number(d.as_secs_f64() * 1e3);
    let mut root = Map::new();
    root.insert("bench", Value::from("mux_scatter"));
    root.insert("case", Value::from(format!("{FAN}-wide {ROWS}x{COLS} select scatter")));
    root.insert("mux_ms_mean", ms(mux_sample.mean()));
    root.insert("classic_ms_mean", ms(cls_sample.mean()));
    root.insert("mux_ms_p50", ms(mux_sample.percentile(0.5)));
    root.insert("classic_ms_p50", ms(cls_sample.percentile(0.5)));
    root.insert(
        "mux_scatters_per_sec",
        Value::Number(1.0 / mux_sample.mean().as_secs_f64().max(1e-12)),
    );
    root.insert("mux_vs_pooled", Value::Number(speedup));
    root.insert("mux_sockets", Value::from(mux_sockets));
    root.insert("classic_sockets", Value::from(cls_sockets));
    // the pin CI actually gates on: the whole muxed scatter (warmup and
    // all rounds) rode exactly one connection
    root.insert(
        "single_conn",
        Value::Number(if mux_sockets == 1 { 1.0 } else { 0.0 }),
    );
    let out = json::to_string_pretty(&Value::Object(root));
    // cargo runs benches from the package root (rust/); the tracking file
    // lives at the repo root next to ROADMAP.md
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_PR8.json"
    } else {
        "BENCH_PR8.json"
    };
    match std::fs::write(path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

//! Micro-benchmarks of the coordinator hot paths (§Perf in
//! EXPERIMENTS.md): substrate costs that bound the pipeline's throughput.
//!
//! Run: `cargo bench --bench micro_hotpath`

#[path = "common.rs"]
mod common;

use std::sync::Arc;
use std::time::Duration;

use alaas::cache::DataCache;
use alaas::json;
use alaas::pipeline::{run_batcher, BatchPolicy};
use alaas::runtime::backend::{host_scores, host_sqdist};
#[allow(unused_imports)]
use alaas::runtime::backend::ComputeBackend;
use alaas::util::bench::{measure, measure_for, Table};
use alaas::util::chan::bounded;
use alaas::util::mat::Mat;
use alaas::util::rng::Rng;
use alaas::util::topk;

fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
    Mat::from_vec((0..r * c).map(|_| rng.normal_f32()).collect(), r, c)
}

fn main() {
    let mut rng = Rng::new(1);
    let mut table = Table::new(
        "micro hot paths",
        &["op", "per-op", "ops/sec", "notes"],
    );
    let budget = Duration::from_millis(600);

    // channel send+recv round trip
    {
        let (tx, rx) = bounded(1024);
        let s = measure_for(budget, || {
            for i in 0..1000 {
                tx.send(i).unwrap();
            }
            for _ in 0..1000 {
                rx.recv().unwrap();
            }
        });
        let per = s.mean().as_nanos() as f64 / 1000.0;
        table.row(&[
            "chan send+recv".into(),
            format!("{per:.0}ns"),
            format!("{:.2}M", 1e3 / per * 1e3 / 1e3),
            "bounded(1024), single thread".into(),
        ]);
    }

    // batcher throughput
    {
        let s = measure_for(budget, || {
            let (tx_in, rx_in) = bounded(4096);
            let (tx_out, rx_out) = bounded(4096);
            let h = std::thread::spawn(move || {
                run_batcher(
                    &rx_in,
                    &tx_out,
                    BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(5) },
                )
            });
            for i in 0..4000 {
                tx_in.send(i).unwrap();
            }
            drop(tx_in);
            h.join().unwrap();
            drop(rx_out);
        });
        let per = s.mean().as_nanos() as f64 / 4000.0;
        table.row(&[
            "batcher item".into(),
            format!("{per:.0}ns"),
            format!("{:.2}M/s", 1e9 / per / 1e6),
            "max_batch 16".into(),
        ]);
    }

    // cache get (hit) / put
    {
        let cache = DataCache::new(256 << 20, 16, true);
        for i in 0..1000 {
            cache.put(&format!("k{i}"), Arc::new(vec![0.0f32; 3072]));
        }
        let s = measure_for(budget, || {
            for i in 0..1000 {
                let _ = cache.get(&format!("k{i}"));
            }
        });
        let per = s.mean().as_nanos() as f64 / 1000.0;
        table.row(&[
            "cache hit".into(),
            format!("{per:.0}ns"),
            format!("{:.2}M/s", 1e9 / per / 1e6),
            "3072-f32 tensors, 16 shards".into(),
        ]);
    }

    // JSON parse + serialize of an RPC-sized frame
    {
        let frame = r#"{"id":42,"method":"query","params":{"session":"s1","budget":1000,"strategy":"least_confidence","wait_ms":60000}}"#;
        let s = measure_for(budget, || {
            let v = json::parse(frame).unwrap();
            let _ = json::to_string(&v);
        });
        let per = s.mean().as_nanos() as f64;
        table.row(&[
            "json rpc roundtrip".into(),
            format!("{per:.0}ns"),
            format!("{:.2}M/s", 1e9 / per / 1e6),
            format!("{}B frame", frame.len()),
        ]);
    }

    // top-k over 100k scores (uncertainty selection hot loop)
    {
        let scores: Vec<f32> = (0..100_000).map(|_| rng.f32()).collect();
        let s = measure(2, 10, || {
            let _ = topk::top_k_desc(&scores, 10_000);
        });
        table.row(&[
            "top-10k of 100k".into(),
            format!("{:.2}ms", s.mean().as_secs_f64() * 1e3),
            format!("{:.1}M scores/s", 0.1 / s.mean().as_secs_f64()),
            "LC selection core".into(),
        ]);
    }

    // host scores vs pjrt scores (L1 kernel vs host reference)
    {
        let logits = rand_mat(&mut rng, 128, 10);
        let s = measure(3, 20, || {
            let _ = host_scores(&logits);
        });
        table.row(&[
            "host scores b128".into(),
            format!("{:.1}us", s.mean().as_secs_f64() * 1e6),
            format!("{:.2}M img/s", 128.0 / s.mean().as_secs_f64() / 1e6),
            "rust reference".into(),
        ]);
        let backend = common::backend(1);
        if backend.name() == "pjrt" {
            let s = measure(3, 20, || {
                let _ = backend.scores(&logits).unwrap();
            });
            table.row(&[
                "pjrt scores b128".into(),
                format!("{:.1}us", s.mean().as_secs_f64() * 1e6),
                format!("{:.3}M img/s", 128.0 / s.mean().as_secs_f64() / 1e6),
                "fused pallas kernel via PJRT".into(),
            ]);
            // forward (the serving hot path unit)
            let imgs = rand_mat(&mut rng, 16, 3072);
            let w = Mat::zeros(64, 10);
            let b = vec![0.0f32; 10];
            let s = measure(3, 20, || {
                let _ = backend.forward(&imgs, &w, &b).unwrap();
            });
            table.row(&[
                "pjrt forward b16".into(),
                format!("{:.2}ms", s.mean().as_secs_f64() * 1e3),
                format!("{:.0} img/s", 16.0 / s.mean().as_secs_f64()),
                "trunk+head+scores, 1 worker".into(),
            ]);
            let imgs = rand_mat(&mut rng, 128, 3072);
            let s = measure(3, 20, || {
                let _ = backend.forward(&imgs, &w, &b).unwrap();
            });
            table.row(&[
                "pjrt forward b128".into(),
                format!("{:.2}ms", s.mean().as_secs_f64() * 1e3),
                format!("{:.0} img/s", 128.0 / s.mean().as_secs_f64()),
                "batch amortization (fig4c)".into(),
            ]);
            // sqdist tile through the pallas kernel
            let x = rand_mat(&mut rng, 256, 64);
            let y = rand_mat(&mut rng, 256, 64);
            let s = measure(3, 20, || {
                let _ = backend.sqdist(&x, &y).unwrap();
            });
            table.row(&[
                "pjrt sqdist 256x256".into(),
                format!("{:.2}ms", s.mean().as_secs_f64() * 1e3),
                format!("{:.1}M pairs/s", 65.536 / s.mean().as_secs_f64() / 1e3),
                "tiled MXU kernel".into(),
            ]);
        }
    }

    // host sqdist (the strategy-side incremental fallback)
    {
        let x = rand_mat(&mut rng, 256, 64);
        let y = rand_mat(&mut rng, 256, 64);
        let s = measure(3, 20, || {
            let _ = host_sqdist(&x, &y).unwrap();
        });
        table.row(&[
            "host sqdist 256x256".into(),
            format!("{:.2}ms", s.mean().as_secs_f64() * 1e3),
            format!("{:.1}M pairs/s", 65.536 / s.mean().as_secs_f64() / 1e3),
            "rust reference".into(),
        ]);
    }

    table.print();
}

//! Fig 5b: PSHEA multi-round auto-selection traces on the two datasets
//! (cifarsim / svhnsim stand-ins for CIFAR-10 / SVHN).
//!
//! Paper shape: the agent launches all 7 candidates, eliminates round by
//! round, and *different datasets keep different strategies* — the
//! motivation for auto-selection (no strategy wins everywhere).
//!
//! Run: `cargo bench --bench fig5b_pshea`

#[path = "common.rs"]
mod common;

use alaas::agent::{run_pshea, PsheaConfig};
use alaas::data::{generate, DatasetSpec};
use alaas::sim::AlExperiment;
use alaas::trainer::TrainConfig;
use alaas::util::bench::Table;

const ROUNDS: usize = 8;
const ROUND_BUDGET: usize = 200;

fn run_dataset(name: &str, spec: DatasetSpec, backend: std::sync::Arc<dyn alaas::runtime::backend::ComputeBackend>) -> (String, usize, f64) {
    eprintln!("[fig5b] embedding {name}...");
    let gen = generate(&spec);
    let mut exp = AlExperiment::from_generated(
        backend,
        &gen,
        spec.num_classes,
        TrainConfig::default(),
        spec.seed,
    )
    .expect("experiment");
    let (_, base) = exp.baseline().expect("baseline");

    let candidates: Vec<String> =
        alaas::strategies::candidate_names().into_iter().map(str::to_string).collect();
    let cfg = PsheaConfig {
        target_accuracy: 0.999, // run the full 8 rounds unless converged
        max_budget: 1_000_000,
        round_budget: ROUND_BUDGET,
        max_rounds: ROUNDS,
        converge_rounds: 0,
        converge_eps: 0.0,
        min_history: 3,
        initial_accuracy: Some(base.top1),
    };
    let trace = run_pshea(&mut exp, &candidates, &cfg).expect("pshea");

    let mut table = Table::new(
        &format!("Fig 5b — PSHEA trace on {name} (baseline {:.3})", base.top1),
        &["Round", "Live arms", "Best acc", "Eliminated"],
    );
    for r in 0..trace.rounds {
        let live = trace.round(r).count();
        let best = trace
            .round(r)
            .map(|rec| rec.accuracy)
            .fold(f64::MIN, f64::max);
        let elim: Vec<&str> = trace
            .round(r)
            .filter(|rec| rec.eliminated)
            .map(|rec| rec.strategy.as_str())
            .collect();
        table.row(&[
            format!("{r}"),
            format!("{live}"),
            format!("{best:.4}"),
            if elim.is_empty() { "-".to_string() } else { elim.join(", ") },
        ]);
    }
    table.print();
    println!(
        "{name}: survivor = {}, budget {} labels, best acc {:.4} (stop: {:?})",
        trace.recommendation().unwrap_or("(none)"),
        trace.total_budget,
        trace.best_accuracy,
        trace.stop
    );
    (
        trace.recommendation().unwrap_or("(none)").to_string(),
        trace.total_budget,
        trace.best_accuracy,
    )
}

fn main() {
    let backend = common::backend(2);
    let (s1, _, _) = run_dataset(
        "cifarsim",
        DatasetSpec::cifarsim(5).with_sizes(500, 3000, 800),
        backend.clone(),
    );
    let (s2, _, _) = run_dataset(
        "svhnsim",
        DatasetSpec::svhnsim(5).with_sizes(500, 3000, 800),
        backend,
    );
    println!(
        "\npaper shape check: different datasets keep different strategies \
         (cifarsim -> {s1}, svhnsim -> {s2}); auto-selection is necessary."
    );
}

//! Pooled vs per-call dialing for worker RPCs (DESIGN.md §Wire): the same
//! echo exchange driven through a `ConnPool` with reuse on
//! (`max_idle_per_peer = 4`) and off (`= 0`: every call dials and
//! `hello`-negotiates a fresh connection), for a small control-plane call
//! and for the 10k x 64 `select_shard`-sized scatter payload.
//!
//! Run: `cargo bench --bench conn_pool`
//!
//! Besides the table, the bench writes a machine-readable `BENCH_PR4.json`
//! at the repo root; CI's bench-regression gate (`tools/bench_gate.py`)
//! checks its ratios against `tools/bench_baseline.json`.

use std::net::TcpListener;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use alaas::json::{self, Map, Value};
use alaas::metrics::Registry;
use alaas::server::pool::{ConnPool, PoolConfig};
use alaas::server::rpc;
use alaas::server::wire::{self, Payload, WireMode};
use alaas::util::bench::{fmt_dur, measure, Sample, Table};
use alaas::util::mat::Mat;
use alaas::util::rng::Rng;

const SCATTER_ROWS: usize = 10_000;
const SCATTER_COLS: usize = 64;

/// Loopback RPC server speaking the real dispatch loop (`serve_conn`):
/// answers `hello` (so pooled dials negotiate the binary wire exactly as
/// against an `AlServer`) and echoes `echo` params back as the result.
fn start_echo_server() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap().to_string();
    let shutdown = Arc::new(AtomicBool::new(false));
    std::thread::spawn(move || {
        let metrics = Registry::new();
        for conn in listener.incoming() {
            let Ok(mut stream) = conn else { continue };
            let metrics = metrics.clone();
            let shutdown = shutdown.clone();
            std::thread::spawn(move || {
                rpc::serve_conn(
                    &mut stream,
                    "bench",
                    &shutdown,
                    &metrics,
                    None,
                    WireMode::Binary,
                    |method, params, _mode| match method {
                        "hello" => {
                            // mux off: this bench compares pooled vs per-call
                            // dialing on the classic one-RPC-per-conn path
                            Ok(Payload::json(wire::hello_reply(
                                &params.value,
                                WireMode::Binary,
                                false,
                            )))
                        }
                        "echo" => Ok(params.to_payload()),
                        other => Err(format!("unknown method '{other}'")),
                    },
                );
            });
        }
    });
    addr
}

struct CaseStats {
    pooled: Sample,
    per_call: Sample,
    pooled_dials: u64,
    per_call_dials: u64,
}

fn run_case(addr: &str, params: &Payload, warmup: usize, runs: usize) -> CaseStats {
    let mut samples = Vec::new();
    let mut dials = Vec::new();
    for max_idle in [4usize, 0] {
        let metrics = Registry::new();
        let pool = ConnPool::new(
            PoolConfig { max_idle_per_peer: max_idle, idle_timeout_ms: 60_000 },
            WireMode::Binary,
            Some(metrics.clone()),
        );
        let sample = measure(warmup, runs, || {
            let body = pool.call(addr, "echo", params, None).expect("echo call");
            assert!(!body.value.is_null());
        });
        samples.push(sample);
        dials.push(metrics.counter("pool.dials").load(std::sync::atomic::Ordering::Relaxed));
    }
    let per_call = samples.pop().unwrap();
    let pooled = samples.pop().unwrap();
    let per_call_dials = dials.pop().unwrap();
    let pooled_dials = dials.pop().unwrap();
    CaseStats { pooled, per_call, pooled_dials, per_call_dials }
}

fn case_obj(s: &CaseStats) -> Value {
    let ms = |d: Duration| Value::Number(d.as_secs_f64() * 1e3);
    let cps = |smp: &Sample| Value::Number(1.0 / smp.mean().as_secs_f64().max(1e-12));
    let mut m = Map::new();
    m.insert("pooled_ms_mean", ms(s.pooled.mean()));
    m.insert("per_call_ms_mean", ms(s.per_call.mean()));
    m.insert("pooled_ms_p50", ms(s.pooled.percentile(0.5)));
    m.insert("per_call_ms_p50", ms(s.per_call.percentile(0.5)));
    m.insert("pooled_calls_per_sec", cps(&s.pooled));
    m.insert("per_call_calls_per_sec", cps(&s.per_call));
    m.insert(
        "pooled_speedup",
        Value::Number(
            s.per_call.mean().as_secs_f64() / s.pooled.mean().as_secs_f64().max(1e-12),
        ),
    );
    m.insert("pooled_dials", Value::from(s.pooled_dials));
    m.insert("per_call_dials", Value::from(s.per_call_dials));
    Value::Object(m)
}

fn main() {
    let addr = start_echo_server();

    // small control-plane call: the agent-loop / probe shape where the
    // dial used to dominate the payload
    let mut p = Map::new();
    p.insert("session", Value::from("bench"));
    p.insert("budget", Value::from(16usize));
    let small = Payload::json(Value::Object(p));
    let small_stats = run_case(&addr, &small, 20, 200);

    // 10k x 64 scatter payload: the select_shard refine shape from
    // benches/rpc_wire.rs, now over pooled vs fresh connections
    let mut rng = Rng::new(7);
    let m = Mat::from_vec(
        (0..SCATTER_ROWS * SCATTER_COLS).map(|_| rng.normal_f32()).collect(),
        SCATTER_ROWS,
        SCATTER_COLS,
    );
    let mut scatter = Payload::default();
    let ph = scatter.stash_mat(m);
    let mut sp = Map::new();
    sp.insert("cand_emb", ph);
    sp.insert("scan_ms", Value::Number(12.5));
    scatter.value = Value::Object(sp);
    let scatter_stats = run_case(&addr, &scatter, 2, 15);

    let mut table = Table::new(
        &format!(
            "conn_pool: pooled vs per-call dialing (small call + {SCATTER_ROWS}x{SCATTER_COLS} scatter)"
        ),
        &["case", "pooled(mean)", "per_call(mean)", "speedup", "pooled dials", "per-call dials"],
    );
    for (name, s) in [("small", &small_stats), ("scatter", &scatter_stats)] {
        table.row(&[
            name.to_string(),
            fmt_dur(s.pooled.mean()),
            fmt_dur(s.per_call.mean()),
            format!(
                "{:.2}x",
                s.per_call.mean().as_secs_f64() / s.pooled.mean().as_secs_f64().max(1e-12)
            ),
            s.pooled_dials.to_string(),
            s.per_call_dials.to_string(),
        ]);
    }
    table.print();

    let mut root = Map::new();
    root.insert("bench", Value::from("conn_pool"));
    root.insert("case", Value::from(format!("small + {SCATTER_ROWS}x{SCATTER_COLS}")));
    root.insert("small", case_obj(&small_stats));
    root.insert("scatter", case_obj(&scatter_stats));
    let out = json::to_string_pretty(&Value::Object(root));
    // cargo runs benches from the package root (rust/); the tracking file
    // lives at the repo root next to ROADMAP.md
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_PR4.json"
    } else {
        "BENCH_PR4.json"
    };
    match std::fs::write(path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

//! Fig 4c: end-to-end throughput vs inference batch size over the
//! simulated S3 store.
//!
//! Paper shape: BS 1 ≈ BS 2 (transmission-dominated), steep rise 4 -> 16
//! (compute amortizes across the batch), plateau past 16 (compute
//! capacity reached).
//!
//! Run: `cargo bench --bench fig4c_batch_size`

#[path = "common.rs"]
mod common;

use std::time::{Duration, Instant};

use alaas::cache::DataCache;
use alaas::data::DatasetSpec;
use alaas::pipeline::{run_pipeline, BatchPolicy, DataflowMode, PipelineParams};
use alaas::trainer::LinearHead;
use alaas::util::bench::Table;

const POOL: usize = 2000;
const RUNS: usize = 2;

fn main() {
    let backend = common::backend(2);
    let store = common::s3_store();
    let spec = DatasetSpec::cifarsim(7).with_sizes(0, POOL, 0);
    let manifest = common::provision(&store, &spec, "f4c");
    let head = LinearHead::zeros(64, 10);

    let mut table = Table::new(
        "Fig 4c — end-to-end throughput vs inference batch size (cifarsim over s3sim)",
        &["Batch size", "Throughput (img/s)", "Elapsed (s)", "vs BS=1"],
    );
    let mut base = None;
    for bs in [1usize, 2, 4, 8, 16, 32, 64] {
        let params = PipelineParams {
            mode: DataflowMode::Pipelined,
            batch: BatchPolicy { max_batch: bs, max_wait: Duration::from_millis(10) },
            fetch_threads: 8,
            preprocess_threads: 4,
            infer_threads: 2,
            ..Default::default()
        };
        let mut best = f64::MAX;
        for _ in 0..RUNS {
            let cache = DataCache::new(0, 1, false); // cold store every run
            let t0 = Instant::now();
            let out =
                run_pipeline(&manifest.pool, &store, &cache, &backend, &head, &params, None)
                    .expect("scan");
            assert_eq!(out.processed, POOL);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        let tput = POOL as f64 / best;
        if base.is_none() {
            base = Some(tput);
        }
        table.row(&[
            format!("{bs}"),
            format!("{tput:.1}"),
            format!("{best:.2}"),
            format!("{:.2}x", tput / base.unwrap()),
        ]);
        eprintln!("[fig4c] bs={bs:3} {tput:8.1} img/s");
    }
    table.print();
    println!(
        "\npaper shape check: near-flat 1->2, dramatic rise 4->16, plateau >= 16."
    );
}

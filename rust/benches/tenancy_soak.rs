//! Multi-tenant soak (DESIGN.md §Tenancy): 64 sessions pushing and
//! querying concurrently against a 4-worker cluster with the admission
//! gate enabled — the coordinator-as-a-service shape of ISSUE 9. Eight
//! client threads each own eight sessions (weights cycling 1..=4),
//! create them through the session API, push a small pool, then drive
//! four query rounds per session while the deficit-round-robin gate
//! schedules the scatters.
//!
//! Run: `cargo bench --bench tenancy_soak`
//!
//! Besides the table, the bench writes a machine-readable
//! `BENCH_PR9.json` at the repo root; CI's bench-regression gate
//! (`tools/bench_gate.py`) pins `all_sessions_completed` at 1.0 — every
//! session must finish its full query schedule (shed retries allowed,
//! lost sessions not). Timings and shed counts are record-only.

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use alaas::cache::DataCache;
use alaas::cluster::{Coordinator, CoordinatorDeps};
use alaas::config::AlaasConfig;
use alaas::data::{generate_into_store, DatasetSpec, Oracle};
use alaas::json::{self, Map, Value};
use alaas::metrics::Registry;
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::HostBackend;
use alaas::server::rpc::RpcError;
use alaas::server::{AlClient, AlServer, ServerDeps, SessionOpts};
use alaas::store::{ObjectStore, StoreRouter};
use alaas::util::bench::Table;

const WORKERS: usize = 4;
const SESSIONS: usize = 64;
const THREADS: usize = 8;
const QUERY_ROUNDS: usize = 4;
const BUDGET: usize = 8;

fn main() {
    let mut cfg = AlaasConfig::default();
    cfg.al_worker.port = 0;
    cfg.store.get_latency_us = 0;
    cfg.store.bandwidth_mib_s = 0.0;
    cfg.store.jitter = 0.0;
    cfg.coordinator.tenancy.enabled = true;
    cfg.coordinator.tenancy.max_sessions = SESSIONS;
    cfg.coordinator.tenancy.max_concurrent = 4;
    cfg.coordinator.tenancy.admit_queue_len = 32;

    let store = Arc::new(StoreRouter::new("/tmp", &cfg.store));
    let spec = DatasetSpec::cifarsim(7).with_sizes(32, 128, 0);
    let scratch: Arc<dyn ObjectStore> = Arc::new(alaas::store::MemStore::new());
    let manifest = generate_into_store(&spec, &scratch, "s3sim", "tenancy-soak");
    for key in scratch.list("").expect("scratch list") {
        store.s3sim_backing().put(&key, &scratch.get(&key).unwrap()).unwrap();
    }
    let oracle = Oracle::load(&scratch, "tenancy-soak").unwrap();
    let init_ids: Vec<u32> = manifest.init.iter().map(|s| s.id).collect();
    let init_labels = oracle.label(&init_ids);

    let workers: Vec<AlServer> = (0..WORKERS)
        .map(|_| {
            AlServer::start(
                cfg.clone(),
                ServerDeps {
                    store: store.clone(),
                    cache: Arc::new(DataCache::from_config(&cfg.cache)),
                    backend: Arc::new(HostBackend::new()) as Arc<dyn ComputeBackend>,
                    metrics: Registry::new(),
                },
            )
            .expect("worker start")
        })
        .collect();
    let mut coord_cfg = cfg.clone();
    coord_cfg.cluster.workers = workers.iter().map(|w| w.addr().to_string()).collect();
    let coordinator = Coordinator::start(
        coord_cfg,
        CoordinatorDeps {
            backend: Arc::new(HostBackend::new()) as Arc<dyn ComputeBackend>,
            metrics: Registry::new(),
        },
    )
    .expect("coordinator start");
    let addr = coordinator.addr().to_string();

    // setup phase (create + push, ungated) runs before the barrier so the
    // timed window measures only gated query scatters
    let go = Arc::new(Barrier::new(THREADS + 1));
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let addr = addr.clone();
            let manifest = manifest.clone();
            let init_labels = init_labels.clone();
            let go = go.clone();
            std::thread::spawn(move || {
                let mut c = AlClient::connect(&addr).expect("connect");
                let mut tokens = Vec::new();
                for s in 0..SESSIONS / THREADS {
                    let opts = SessionOpts { weight: (s % 4 + 1) as u64, max_workers: 0 };
                    let (_, tok) = c
                        .create_session(&format!("soak-{t}-{s}"), opts)
                        .expect("create")
                        .detach();
                    c.push_data(&tok, &manifest, Some(&init_labels)).expect("push");
                    tokens.push(tok);
                }
                go.wait();
                let mut lat_ms = Vec::new();
                for _ in 0..QUERY_ROUNDS {
                    for tok in &tokens {
                        let q0 = Instant::now();
                        loop {
                            match c.query(tok, BUDGET, Some("least_confidence")) {
                                Ok(_) => break,
                                Err(RpcError::Overloaded { retry_after_ms, .. }) => {
                                    std::thread::sleep(Duration::from_millis(
                                        retry_after_ms.max(1),
                                    ));
                                }
                                Err(e) => panic!("soak query failed: {e}"),
                            }
                        }
                        lat_ms.push(q0.elapsed().as_secs_f64() * 1e3);
                    }
                }
                for tok in &tokens {
                    c.close_session(tok).expect("close");
                }
                (lat_ms, tokens.len())
            })
        })
        .collect();
    go.wait();
    let t0 = Instant::now();
    let mut lat_ms: Vec<f64> = Vec::new();
    let mut completed = 0usize;
    let mut clean = true;
    for h in handles {
        match h.join() {
            Ok((l, n)) => {
                lat_ms.extend(l);
                completed += n;
            }
            Err(_) => clean = false,
        }
    }
    let wall = t0.elapsed();

    let (shed_total, admitted_total) = {
        let mut c = AlClient::connect(&addr).expect("stats connect");
        let v = c.service_stats().expect("service_stats");
        (
            v.get("shed_total").and_then(Value::as_usize).unwrap_or(0),
            v.get("admitted_total").and_then(Value::as_usize).unwrap_or(0),
        )
    };
    coordinator.shutdown();
    for w in workers {
        w.shutdown();
    }

    lat_ms.sort_by(|a, b| a.total_cmp(b));
    let pct = |p: f64| -> f64 {
        if lat_ms.is_empty() {
            return 0.0;
        }
        lat_ms[((lat_ms.len() - 1) as f64 * p).round() as usize]
    };
    let (p50, p99) = (pct(0.50), pct(0.99));
    let qps = lat_ms.len() as f64 / wall.as_secs_f64().max(1e-12);
    let all_done = clean && completed == SESSIONS;

    let mut table = Table::new(
        &format!("tenancy_soak: {SESSIONS} sessions x {WORKERS} workers, gated scatters"),
        &["queries", "p50", "p99", "qps", "admitted", "shed"],
    );
    table.row(&[
        lat_ms.len().to_string(),
        format!("{p50:.2}ms"),
        format!("{p99:.2}ms"),
        format!("{qps:.1}"),
        admitted_total.to_string(),
        shed_total.to_string(),
    ]);
    table.print();
    println!("all sessions completed: {all_done}");

    let mut root = Map::new();
    root.insert("bench", Value::from("tenancy_soak"));
    root.insert("sessions", Value::from(SESSIONS));
    root.insert("workers", Value::from(WORKERS));
    root.insert("p50_ms", Value::Number(p50));
    root.insert("p99_ms", Value::Number(p99));
    root.insert("queries_per_sec", Value::Number(qps));
    root.insert("shed_total", Value::from(shed_total));
    // the pin CI actually gates on: every session finished its full query
    // schedule (shed retries allowed, lost sessions not)
    root.insert(
        "all_sessions_completed",
        Value::Number(if all_done { 1.0 } else { 0.0 }),
    );
    let out = json::to_string_pretty(&Value::Object(root));
    // cargo runs benches from the package root (rust/); the tracking file
    // lives at the repo root next to ROADMAP.md
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_PR9.json"
    } else {
        "BENCH_PR9.json"
    };
    match std::fs::write(path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    if !all_done {
        std::process::exit(1);
    }
}
